//! Hand-rolled CLI (clap is not available offline): subcommands, flags
//! with values, and a help screen.  Used by `main.rs`.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args, and `--key value`
/// / `--flag` options.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from raw argv (excluding the binary name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with('-') {
                return Err(format!("expected a subcommand, got `{cmd}`"));
            }
            out.command = cmd;
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    return Err("stray `--`".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: `{v}` is not an integer")),
        }
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: `{v}` is not a number")),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

pub const HELP: &str = "\
ogasched — online multi-server job scheduling with sublinear regret
(reproduction of Zhao et al., 2023; see DESIGN.md)

USAGE:
    ogasched <COMMAND> [OPTIONS]

COMMANDS:
    run        run one scenario with one policy
               --policy <ogasched|ogasched-hlo|ogasched-mirror|drf|fairness|binpacking|spreading|random>
               --config <file.toml>   scenario config (TOML subset)
               --horizon N --ports N --instances N --resources N
               --rho F --contention F --eta0 F --decay F --seed N
               --runs N --shards N   two-level worker budget: N concurrent
                                     runs x N workers per run (0 = auto
                                     from PALLAS_WORKERS/cores; --workers N
                                     is the legacy alias for --shards)
               fault injection (any non-zero rate arms mid-horizon churn):
               --fault-instance-rate F --fault-port-rate F
               --fault-rack-rate F --fault-rack-size N
               --fault-recover-rate F --fault-seed N
               --fault-release <drain|release>   in-flight units on a failed
                                     instance drain at the next full commit
                                     or are force-released immediately
               --replan-threshold F  shard re-plan when load imbalance
                                     (max shard load x shards / total)
                                     exceeds F (>= 1.0)
               --churn-rebuild       use the from-scratch rebuild arm
                                     instead of incremental maintenance
                                     (bitwise-identical by contract)
               crash resilience (any active knob runs the resilient driver:
               deterministic checkpoints + kill-and-resume recovery, bitwise
               equal to the uninterrupted run by contract):
               --checkpoint-epoch N  snapshot every N slots (0 = only the
                                     implicit slot-0 snapshot; kills then
                                     replay from the start)
               --exec-panic-rate F --exec-stall-rate F --exec-stall-ms N
               --exec-kill-rate F --ckpt-fail-rate F --exec-fault-seed N
               durable self-verifying checkpoint store (PLCK v3 blobs in a
               chain; recovery skips corrupt blobs and falls back):
               --chain-depth N       blobs retained per store (>= 1; the
                                     genesis blob is always pinned)
               --store-dir PATH      persist the chain on disk via
                                     write-temp + flush + atomic rename
                                     (default: in-memory store)
               storage fault injection (deterministic per (slot, seed)):
               --torn-write-rate F --bit-flip-rate F --lost-rename-rate F
               observability (slot-phase spans + metrics; bitwise-inert):
               --obs <off|summary|trace>  summary prints the metric table
                                     after the run; trace also writes
                                     results/obs_events.jsonl and the
                                     Perfetto-loadable results/obs_trace.json
    compare    run the full paper lineup on one scenario (same options)
    serve      sustained-traffic throughput bench: stream arrivals through
               the lock-free ingest queue + batcher and run the slot
               pipeline in both modes (lockstep = bitwise reference,
               overlapped = slot t+1 decide over slot t commit+reward),
               writing BENCH_throughput.json from the obs registry's
               span.slot.ns histogram
               --slots N             slots per (mode, shape) run
               --batch-shapes A,B    batch_events sweep (default 32,128)
               --backpressure [on|off]  block at queue capacity instead
                                     of dropping newest
               --ingest-capacity N --batch-events N --ingest-burst N
               --ewma-alpha F --ewma-epoch N   per-port arrival-rate
                                     EWMA gauges (ingest.rate.port<l>)
               --out <file>          output path (BENCH_throughput.json)
               plus the `run` scenario/policy/parallel options
    figure     regenerate a paper figure/table:
               ogasched figure <fig2|fig3|fig4|fig5|fig6|fig7|table3|regret|sparse|churn|all>
               --horizon N   override T (0 = paper scale)
               --obs <off|summary|trace>   as in `run`
    artifacts  check AOT artifacts and run a PJRT smoke step
    help       show this help

EXAMPLES:
    ogasched compare --horizon 2000
    ogasched figure fig2 --horizon 1000
    ogasched run --policy ogasched-hlo --horizon 500
    ogasched run --fault-instance-rate 0.02 --fault-recover-rate 0.2 --horizon 500
    ogasched run --checkpoint-epoch 20 --exec-kill-rate 0.01 --horizon 500
    ogasched run --checkpoint-epoch 10 --exec-kill-rate 0.01 --chain-depth 3 \
        --torn-write-rate 0.05 --bit-flip-rate 0.05 --horizon 500
    ogasched serve --slots 200 --batch-shapes 16,64 --backpressure on
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = parse("figure fig2 --horizon 500 --verbose");
        assert_eq!(a.command, "figure");
        assert_eq!(a.positional, vec!["fig2"]);
        assert_eq!(a.opt("horizon"), Some("500"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --policy=drf --rho=0.5");
        assert_eq!(a.opt("policy"), Some("drf"));
        assert_eq!(a.opt_f64("rho", 0.7).unwrap(), 0.5);
    }

    #[test]
    fn typed_accessors_error_cleanly() {
        let a = parse("run --horizon abc");
        assert!(a.opt_usize("horizon", 1).is_err());
        assert_eq!(a.opt_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_leading_option() {
        assert!(Args::parse(vec!["--oops".to_string()]).is_err());
    }
}
