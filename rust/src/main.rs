//! `ogasched` binary — the L3 leader entrypoint.
//!
//! See `ogasched help` (cli::HELP) for the command surface.

use ogasched::cli::{Args, HELP};
use ogasched::config::Scenario;
use ogasched::figures;
use ogasched::metrics;
use ogasched::obs;
use ogasched::runtime::{default_dir, HloOgaSched, Manifest};
use ogasched::schedulers::{
    BinPacking, Drf, Fairness, OgaSched, Policy, RandomAlloc, Spreading,
};
use ogasched::sim;
use ogasched::traces::synthesize;
use ogasched::utils::table::Table;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_str() {
        "run" => cmd_run(&args),
        "compare" => cmd_compare(&args),
        "figure" => cmd_figure(&args),
        "serve" => cmd_serve(&args),
        "artifacts" => cmd_artifacts(),
        "help" | "" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(format!("unknown command `{other}`\n\n{HELP}")),
    }
    .map_or_else(
        |e| {
            eprintln!("error: {e}");
            1
        },
        |()| 0,
    );
    std::process::exit(code);
}

/// Build a scenario from --config plus CLI overrides.
fn scenario_from(args: &Args) -> Result<Scenario, String> {
    let mut s = match args.opt("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            Scenario::from_toml(&text)?
        }
        None => Scenario::default(),
    };
    s.horizon = args.opt_usize("horizon", s.horizon)?;
    s.num_ports = args.opt_usize("ports", s.num_ports)?;
    s.num_instances = args.opt_usize("instances", s.num_instances)?;
    s.num_resources = args.opt_usize("resources", s.num_resources)?;
    s.arrival_prob = args.opt_f64("rho", s.arrival_prob)?;
    s.contention = args.opt_f64("contention", s.contention)?;
    s.eta0 = args.opt_f64("eta0", s.eta0)?;
    s.decay = args.opt_f64("decay", s.decay)?;
    s.seed = args.opt_usize("seed", s.seed as usize)? as u64;
    // --workers is the legacy per-run shard budget; --runs/--shards set
    // the two-level split explicitly (0 = auto, see utils::pool)
    s.parallel.shards = args.opt_usize("workers", s.parallel.shards)?;
    s.parallel.runs = args.opt_usize("runs", s.parallel.runs)?;
    s.parallel.shards = args.opt_usize("shards", s.parallel.shards)?;
    // Fault-injection knobs (§Churn): any non-zero rate arms the
    // versioned-topology path in `cmd_run`.
    s.faults.instance_rate = args.opt_f64("fault-instance-rate", s.faults.instance_rate)?;
    s.faults.port_rate = args.opt_f64("fault-port-rate", s.faults.port_rate)?;
    s.faults.rack_rate = args.opt_f64("fault-rack-rate", s.faults.rack_rate)?;
    s.faults.rack_size = args.opt_usize("fault-rack-size", s.faults.rack_size)?;
    s.faults.recover_rate = args.opt_f64("fault-recover-rate", s.faults.recover_rate)?;
    s.faults.seed = args.opt_usize("fault-seed", s.faults.seed as usize)? as u64;
    s.faults.replan_threshold = args.opt_f64("replan-threshold", s.faults.replan_threshold)?;
    if let Some(mode) = args.opt("fault-release") {
        s.faults.release = match mode {
            "drain" => ogasched::coordinator::ReleaseMode::Drain,
            "release" => ogasched::coordinator::ReleaseMode::Release,
            other => return Err(format!("--fault-release: unknown mode `{other}` (drain|release)")),
        };
    }
    // Crash-resilience knobs (§Recover): checkpoint cadence plus the
    // execution-fault stream; any active knob routes `cmd_run` through
    // the resilient kill-and-resume driver.
    s.recovery.checkpoint_epoch =
        args.opt_usize("checkpoint-epoch", s.recovery.checkpoint_epoch)?;
    s.recovery.panic_rate = args.opt_f64("exec-panic-rate", s.recovery.panic_rate)?;
    s.recovery.stall_rate = args.opt_f64("exec-stall-rate", s.recovery.stall_rate)?;
    s.recovery.kill_rate = args.opt_f64("exec-kill-rate", s.recovery.kill_rate)?;
    s.recovery.ckpt_fail_rate = args.opt_f64("ckpt-fail-rate", s.recovery.ckpt_fail_rate)?;
    s.recovery.stall_ms = args.opt_usize("exec-stall-ms", s.recovery.stall_ms as usize)? as u64;
    s.recovery.seed = args.opt_usize("exec-fault-seed", s.recovery.seed as usize)? as u64;
    // Checkpoint-store knobs (§SStore): chain retention, optional disk
    // backing, and the storage-fault stream.
    s.recovery.chain_depth = args.opt_usize("chain-depth", s.recovery.chain_depth)?;
    s.recovery.torn_write_rate = args.opt_f64("torn-write-rate", s.recovery.torn_write_rate)?;
    s.recovery.bit_flip_rate = args.opt_f64("bit-flip-rate", s.recovery.bit_flip_rate)?;
    s.recovery.lost_rename_rate =
        args.opt_f64("lost-rename-rate", s.recovery.lost_rename_rate)?;
    if let Some(dir) = args.opt("store-dir") {
        s.store_dir = Some(dir.to_string());
    }
    // Observability level (§Obs): bitwise-inert by contract, so it can be
    // toggled per-invocation without invalidating any parity baseline.
    if let Some(v) = args.opt("obs") {
        s.obs.level = obs::ObsLevel::parse(v).map_err(|e| format!("--obs: {e}"))?;
    }
    // Streaming-ingest knobs (§SPerf-9, the `serve` driver).  A bare
    // `--backpressure` turns blocking-at-capacity on; `--backpressure
    // off` selects drop-newest explicitly.
    if let Some(v) = args.opt("backpressure") {
        s.ingest.backpressure = match v {
            "on" | "true" => true,
            "off" | "false" => false,
            other => {
                return Err(format!("--backpressure: `{other}` is not on|off"));
            }
        };
    } else if args.has_flag("backpressure") {
        s.ingest.backpressure = true;
    }
    if args.has_flag("ingest") {
        s.ingest.enabled = true;
    }
    s.ingest.capacity = args.opt_usize("ingest-capacity", s.ingest.capacity)?;
    s.ingest.batch_events = args.opt_usize("batch-events", s.ingest.batch_events)?;
    s.ingest.burst = args.opt_usize("ingest-burst", s.ingest.burst)?;
    s.ingest.ewma_alpha = args.opt_f64("ewma-alpha", s.ingest.ewma_alpha)?;
    s.ingest.ewma_epoch = args.opt_usize("ewma-epoch", s.ingest.ewma_epoch)?;
    s.validate()?;
    Ok(s)
}

/// Resolve a `--policy` name against a synthesized problem (shared by
/// `run` and `serve`).
fn build_policy(
    name: &str,
    problem: &ogasched::model::Problem,
    s: &Scenario,
) -> Result<Box<dyn Policy>, String> {
    Ok(match name {
        "ogasched" => Box::new(OgaSched::new(problem, s.eta0, s.decay, s.parallel)),
        "ogasched-hlo" => Box::new(
            HloOgaSched::from_default_dir(problem, s.eta0, s.decay)
                .map_err(|e| format!("{e:#}"))?,
        ),
        "drf" => Box::new(Drf::new()),
        "fairness" => Box::new(Fairness::new()),
        "binpacking" => Box::new(BinPacking::new()),
        "spreading" => Box::new(Spreading::new()),
        "ogasched-mirror" => {
            Box::new(ogasched::schedulers::OgaMirror::new(problem, s.eta0, s.decay, s.parallel))
        }
        "random" => Box::new(RandomAlloc::new(s.seed)),
        other => return Err(format!("unknown policy `{other}`")),
    })
}

/// Flush observability output for a finished command: the metric table at
/// `summary` and above, plus the JSONL + Chrome-trace files at `trace`.
fn obs_finish(s: &Scenario) -> Result<(), String> {
    if !s.obs.enabled() {
        return Ok(());
    }
    println!("{}", obs::export::summary_table().render());
    if s.obs.level == obs::ObsLevel::Trace {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir).map_err(|e| format!("results: {e}"))?;
        let events = dir.join("obs_events.jsonl");
        let trace = dir.join("obs_trace.json");
        obs::export::write_jsonl(&events)?;
        obs::export::write_chrome_trace(&trace)?;
        println!("obs: wrote {} and {}", events.display(), trace.display());
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let s = scenario_from(args)?;
    obs::set_level(s.obs.level);
    let problem = synthesize(&s);
    let name = args.opt("policy").unwrap_or("ogasched");
    let mut policy = build_policy(name, &problem, &s)?;
    if s.recovery.enabled() {
        let rebuild = args.has_flag("churn-rebuild");
        let out = sim::checkpoint::run_resilient_scenario(&s, policy.as_mut(), rebuild)?;
        println!(
            "policy={} T={} avg_reward={:.3} cumulative={:.1} throughput={:.0} slots/s \
             churn: events={} editions={} replans={} \
             recover: ckpts={} ({} rewrites, +{} dropped) kills={} restored_from={:?} \
             worker_faults={} blobs_rejected={} thaw_fallbacks={} arm={}",
            out.churn.result.policy,
            s.horizon,
            out.churn.result.avg_reward(),
            out.churn.result.cumulative_reward,
            out.churn.result.throughput(),
            out.churn.events,
            out.churn.editions,
            out.churn.replans,
            out.checkpoints_written,
            out.checkpoints_rewritten,
            out.checkpoints_failed,
            out.kills,
            out.restored_from,
            out.worker_faults,
            out.blobs_rejected,
            out.thaw_fallbacks,
            if rebuild { "rebuild" } else { "incremental" },
        );
        return obs_finish(&s);
    }
    if s.faults.enabled() {
        let rebuild = args.has_flag("churn-rebuild");
        let out = sim::faults::run_churned_scenario(&s, policy.as_mut(), rebuild)?;
        println!(
            "policy={} T={} avg_reward={:.3} cumulative={:.1} throughput={:.0} slots/s \
             churn: events={} editions={} replans={} arm={}",
            out.result.policy,
            s.horizon,
            out.result.avg_reward(),
            out.result.cumulative_reward,
            out.result.throughput(),
            out.events,
            out.editions,
            out.replans,
            if rebuild { "rebuild" } else { "incremental" },
        );
        return obs_finish(&s);
    }
    let run = sim::run_on_problem(&s, &problem, policy.as_mut());
    println!(
        "policy={} T={} avg_reward={:.3} cumulative={:.1} throughput={:.0} slots/s",
        run.policy,
        s.horizon,
        run.avg_reward(),
        run.cumulative_reward,
        run.throughput()
    );
    obs_finish(&s)
}

fn cmd_compare(args: &Args) -> Result<(), String> {
    let s = scenario_from(args)?;
    obs::set_level(s.obs.level);
    let results = sim::run_paper_lineup(&s);
    let oga = results[0].clone();
    let mut table =
        Table::new(&["policy", "avg reward", "cumulative", "OGA improvement", "slots/s"]);
    for run in &results {
        let imp = if run.policy == "OGASCHED" {
            "-".into()
        } else {
            format!("{:+.2}%", metrics::improvement_pct(&oga, run))
        };
        table.push(&[
            run.policy.clone(),
            format!("{:.2}", run.avg_reward()),
            format!("{:.1}", run.cumulative_reward),
            imp,
            format!("{:.0}", run.throughput()),
        ]);
    }
    println!(
        "scenario `{}`: |L|={} |R|={} K={} T={} rho={} contention={}",
        s.name, s.num_ports, s.num_instances, s.num_resources, s.horizon,
        s.arrival_prob, s.contention
    );
    println!("{}", table.render());
    obs_finish(&s)
}

fn cmd_figure(args: &Args) -> Result<(), String> {
    let s = scenario_from(args)?;
    obs::set_level(s.obs.level);
    let id = args.positional.first().map(String::as_str).unwrap_or("all");
    let horizon = args.opt_usize("horizon", 0)?;
    if id == "all" {
        for id in figures::ALL_IDS {
            println!("{}", figures::run_by_id(id, horizon)?);
        }
        return obs_finish(&s);
    }
    println!("{}", figures::run_by_id(id, horizon)?);
    obs_finish(&s)
}

/// Sustained-traffic throughput harness (§SPerf-9): drive one policy
/// through the streaming ingest queue + batcher under both pipeline
/// modes at each requested batch shape, read slot latency from the obs
/// registry's "span.slot.ns" histogram (not a bespoke timer), and write
/// `BENCH_throughput.json`.  Cross-mode cumulative rewards are asserted
/// equal per shape — the parity contract rides along with every bench.
fn cmd_serve(args: &Args) -> Result<(), String> {
    use ogasched::coordinator::{run_pipeline, PipelineMode, ShardedLeader};
    use ogasched::sim::ingest::{StreamArrivals, StreamParams};

    let mut s = scenario_from(args)?;
    s.ingest.enabled = true;
    // the bench reads registry histograms, so obs must be at least on
    if !s.obs.enabled() {
        s.obs.level = obs::ObsLevel::Summary;
    }
    obs::set_level(s.obs.level);
    let slots = args.opt_usize("slots", s.horizon.min(400))?;
    if slots == 0 {
        return Err("--slots must be > 0".into());
    }
    let shapes: Vec<usize> = match args.opt("batch-shapes") {
        None => vec![s.ingest.batch_events, s.ingest.batch_events * 4],
        Some(v) => v
            .split(',')
            .map(|t| {
                t.trim()
                    .parse::<usize>()
                    .map_err(|_| format!("--batch-shapes: `{t}` is not an integer"))
            })
            .collect::<Result<_, _>>()?,
    };
    if shapes.is_empty() || shapes.contains(&0) {
        return Err("--batch-shapes needs positive batch sizes".into());
    }
    let out_path = args.opt("out").unwrap_or("BENCH_throughput.json");
    let name = args.opt("policy").unwrap_or("ogasched");
    let problem = synthesize(&s);

    let mut table = Table::new(&[
        "mode", "batch", "slots/s", "events/s", "p50 us", "p99 us", "max us", "dropped",
    ]);
    let mut rows = String::new();
    for &shape in &shapes {
        let mut cumulative: Option<f64> = None;
        for mode in [PipelineMode::Lockstep, PipelineMode::Overlapped] {
            obs::reset();
            let mut leader = ShardedLeader::new(&problem, s.parallel.shards);
            let mut policy = build_policy(name, &problem, &s)?;
            policy.reset(&problem);
            let params = StreamParams {
                batch_events: shape,
                ..StreamParams::from_config(&s.ingest)
            };
            let mut arr = StreamArrivals::new(problem.num_ports(), params, s.seed ^ 0x1A57);
            let out = run_pipeline(&mut leader, policy.as_mut(), &mut arr, slots, mode);
            match cumulative {
                None => cumulative = Some(out.result.cumulative_reward),
                Some(want) => {
                    if out.result.cumulative_reward != want {
                        return Err(format!(
                            "pipeline parity violated at batch_events={shape}: \
                             lockstep cumulative {want}, overlapped {}",
                            out.result.cumulative_reward
                        ));
                    }
                }
            }
            arr.drain_in_flight();
            arr.queue().publish_counters();
            let reg = obs::registry();
            let hist = reg.histogram("span.slot.ns").snapshot();
            let accepted = arr.queue().pushed();
            let dropped = arr.queue().dropped();
            let waits = arr.queue().backpressure_waits();
            let elapsed = out.result.elapsed_secs.max(1e-9);
            let slots_per_sec = slots as f64 / elapsed;
            let events_per_sec = accepted as f64 / elapsed;
            if !rows.is_empty() {
                rows.push_str(",\n");
            }
            rows.push_str(&format!(
                "    {{\"mode\": \"{}\", \"batch_events\": {shape}, \"slots\": {slots}, \
                 \"elapsed_secs\": {elapsed:.6}, \"slots_per_sec\": {slots_per_sec:.1}, \
                 \"events_per_sec\": {events_per_sec:.1}, \"events_total\": {accepted}, \
                 \"batches_total\": {}, \"dropped\": {dropped}, \
                 \"backpressure_waits\": {waits}, \"slot_ns\": {{\"count\": {}, \
                 \"p50\": {}, \"p99\": {}, \"max\": {}}}}}",
                mode.name(),
                arr.batches_total(),
                hist.count,
                hist.p50(),
                hist.p99(),
                hist.max,
            ));
            table.push(&[
                mode.name().into(),
                format!("{shape}"),
                format!("{slots_per_sec:.0}"),
                format!("{events_per_sec:.0}"),
                format!("{:.1}", hist.p50() as f64 / 1e3),
                format!("{:.1}", hist.p99() as f64 / 1e3),
                format!("{:.1}", hist.max as f64 / 1e3),
                format!("{dropped}"),
            ]);
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"throughput\",\n  \"provenance\": \"measured (ogasched serve; \
         slot latency from the obs registry span.slot.ns histogram)\",\n  \
         \"policy\": \"{name}\",\n  \"slots\": {slots},\n  \"shards\": {},\n  \
         \"backpressure\": {},\n  \"runs\": [\n{rows}\n  ]\n}}\n",
        s.parallel.shards, s.ingest.backpressure,
    );
    std::fs::write(out_path, json).map_err(|e| format!("{out_path}: {e}"))?;
    println!(
        "serve: policy={name} slots={slots} shards={} shapes={shapes:?} backpressure={}",
        s.parallel.shards, s.ingest.backpressure
    );
    println!("{}", table.render());
    println!("serve: wrote {out_path}");
    obs_finish(&s)
}

fn cmd_artifacts() -> Result<(), String> {
    let dir = default_dir();
    let manifest = Manifest::load(&dir)?;
    println!("artifact dir: {}", dir.display());
    for b in &manifest.buckets {
        println!(
            "  bucket {:<8} L={:<4} R={:<5} K={:<2} {}",
            b.name,
            b.l,
            b.r,
            b.k,
            b.path.display()
        );
    }
    // PJRT smoke: run a few compiled steps on the smallest bucket
    let small = manifest
        .buckets
        .iter()
        .min_by_key(|b| b.volume())
        .ok_or_else(|| format!("artifact manifest at {} lists no buckets", dir.display()))?;
    let mut s = Scenario::small();
    s.num_ports = small.l;
    s.num_instances = small.r;
    s.num_resources = small.k;
    let problem = synthesize(&s);
    let mut exec = ogasched::runtime::OgaStepExecutor::new(&manifest, &problem)
        .map_err(|e| format!("{e:#}"))?;
    let x = vec![1.0; problem.num_ports()];
    let mut reward = 0.0;
    for _ in 0..5 {
        reward = exec.step(&x, 1.0).map_err(|e| format!("{e:#}"))?.q;
    }
    println!("PJRT smoke OK: 5 compiled steps on `{}`, q(5th)={reward:.3}", small.name);
    Ok(())
}
