//! Bench: regenerate Fig. 4 (eta0 / decay sensitivity).

use ogasched::benchlib::{scaled, time_fn, Reporter};
use ogasched::figures::fig4;

fn main() {
    let mut rep = Reporter::new("fig4_hyperparams");
    let t = scaled(2000, 100);
    rep.record(time_fn(&format!("fig4 sweeps T={t}"), 0, 1, || {
        std::hint::black_box(&fig4::run(t));
    }));
    rep.section("Fig. 4 output", fig4::run(t));
    rep.finish();
}
