//! Bench: regenerate Fig. 3 (scalability over |R|, |L|, contention).

use ogasched::benchlib::{scaled, time_fn, Reporter};
use ogasched::figures::fig3;

fn main() {
    let mut rep = Reporter::new("fig3_scalability");
    let t = scaled(2000, 100);
    rep.record(time_fn(&format!("fig3 sweeps T={t}"), 0, 1, || {
        std::hint::black_box(&fig3::run(t));
    }));
    rep.section("Fig. 3 output", fig3::run(t));
    rep.finish();
}
