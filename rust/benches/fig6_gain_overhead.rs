//! Bench: regenerate Fig. 6 (gain vs penalty per contention level).

use ogasched::benchlib::{scaled, time_fn, Reporter};
use ogasched::figures::fig6;

fn main() {
    let mut rep = Reporter::new("fig6_gain_overhead");
    let t = scaled(2000, 100);
    rep.record(time_fn(&format!("fig6 sweep T={t}"), 0, 1, || {
        std::hint::black_box(&fig6::run(t));
    }));
    rep.section("Fig. 6 output", fig6::run(t));
    rep.finish();
}
