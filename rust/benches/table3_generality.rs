//! Bench: regenerate Tab. 3 (generality & robustness grid).

use ogasched::benchlib::{scaled, time_fn, Reporter};
use ogasched::figures::table3;

fn main() {
    let mut rep = Reporter::new("table3_generality");
    let t = scaled(2000, 50);
    rep.record(time_fn(&format!("table3 grid (base T={t})"), 0, 1, || {
        std::hint::black_box(&table3::run(t));
    }));
    rep.section("Tab. 3 output", table3::run(t));
    rep.finish();
}
