//! Ablation bench: Thm. 1 — empirical regret vs T and vs |L| with the
//! offline stationary oracle; verifies sublinearity (exponent < 1).

use ogasched::ExecBudget;
use ogasched::benchlib::{policy_table, scaled, time_fn, Reporter};
use ogasched::config::Scenario;
use ogasched::figures::regret_fig;
use ogasched::schedulers::{OgaMirror, OgaSched};
use ogasched::sim;
use ogasched::traces::synthesize;

fn main() {
    let mut rep = Reporter::new("ablation_regret");
    let t = scaled(2000, 100);
    rep.record(time_fn(&format!("regret curves (base T={t})"), 0, 1, || {
        std::hint::black_box(&regret_fig::run(t));
    }));
    rep.section("Thm. 1 ablation output", regret_fig::run(t));

    // Sec. 3.5 side claim: mirror-ascent "related techniques" stay
    // competitive with the additive OGA step.
    let mut s = Scenario::default();
    s.horizon = t;
    let p = synthesize(&s);
    let additive = sim::run_on_problem(&s, &p, &mut OgaSched::new(&p, s.eta0, s.decay, ExecBudget::auto()));
    let mirror = sim::run_on_problem(&s, &p, &mut OgaMirror::new(&p, s.eta0, s.decay, ExecBudget::auto()));
    rep.section(
        "additive vs mirror ascent (default scenario)",
        policy_table(
            &["variant", "avg reward", "cumulative"],
            &[
                ("OGA (additive)".into(), vec![additive.avg_reward(), additive.cumulative_reward]),
                ("OGA (mirror)".into(), vec![mirror.avg_reward(), mirror.cumulative_reward]),
            ],
            2,
        ),
    );
    rep.finish();
}
