//! Bench: regenerate Fig. 5 (|L|=100, |R|=1024 large-scale validation).
//! Paper T=10000; scale via OGASCHED_BENCH_SCALE (default here: 1000
//! slots — the full horizon takes a long while on one box).

use ogasched::benchlib::{bench_scale, time_fn, Reporter};
use ogasched::figures::fig5;

fn main() {
    let mut rep = Reporter::new("fig5_large_scale");
    let t = ((10_000.0 * bench_scale() * 0.1) as usize).max(50);
    rep.record(time_fn(&format!("fig5 large-scale T={t}"), 0, 1, || {
        std::hint::black_box(&fig5::run(t));
    }));
    rep.section("Fig. 5 output", fig5::run(t));
    rep.finish();
}
