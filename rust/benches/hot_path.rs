//! Hot-path bench: per-slot latency of the whole L3 loop and its parts —
//! gradient, projection, reward, native full step, and the PJRT-compiled
//! step (when artifacts are present).  This is the §Perf baseline /
//! after table of EXPERIMENTS.md.

use ogasched::benchlib::{time_fn, Reporter};
use ogasched::config::Scenario;
use ogasched::oga::gradient::{gradient, GradScratch};
use ogasched::oga::projection::project;
use ogasched::oga::{LearningRate, OgaState};
use ogasched::reward::slot_reward_scratch;
use ogasched::runtime::{default_dir, Manifest, OgaStepExecutor};
use ogasched::traces::synthesize;
use ogasched::utils::rng::Rng;

fn main() {
    let mut rep = Reporter::new("hot_path");
    for (name, mut scenario) in [
        ("small 4x16x4", Scenario::small()),
        ("default 10x128x6", Scenario::default()),
        ("large 100x1024x6", Scenario::large_scale()),
    ] {
        scenario.horizon = 1;
        let p = synthesize(&scenario);
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..p.num_ports())
            .map(|_| if rng.bernoulli(0.7) { 1.0 } else { 0.0 })
            .collect();
        let y: Vec<f64> = (0..p.decision_len()).map(|_| rng.uniform(0.0, 1.0)).collect();

        let mut grad = vec![0.0; p.decision_len()];
        let mut scratch = GradScratch::default();
        rep.record(time_fn(&format!("gradient          {name}"), 3, 50, || {
            gradient(&p, &x, &y, &mut grad, &mut scratch);
            std::hint::black_box(&grad);
        }));
        rep.record(time_fn(&format!("projection(auto)  {name}"), 3, 50, || {
            let mut z = y.clone();
            project(&p, &mut z, 0);
            std::hint::black_box(&z);
        }));
        let mut quota = vec![0.0; p.num_resources];
        rep.record(time_fn(&format!("reward            {name}"), 3, 50, || {
            std::hint::black_box(slot_reward_scratch(&p, &x, &y, &mut quota));
        }));
        let mut state = OgaState::new(&p, LearningRate::Constant(0.5), 0);
        rep.record(time_fn(&format!("native OGA step   {name}"), 3, 50, || {
            state.step(&p, &x);
        }));
        if let Ok(manifest) = Manifest::load(default_dir()) {
            if let Ok(mut exec) = OgaStepExecutor::new(&manifest, &p) {
                rep.record(time_fn(&format!("PJRT OGA step     {name}"), 3, 50, || {
                    std::hint::black_box(exec.step(&x, 0.5).expect("pjrt"));
                }));
            }
        }
    }
    rep.finish();
}
