//! Hot-path bench: per-slot latency of the whole L3 loop and its parts —
//! gradient, projection, reward, the native edge-major (CSR) OGA step,
//! the seed's dense [L, R, K] step as the before/after baseline, and the
//! PJRT-compiled step (when artifacts are present).  This is the §Perf
//! baseline/after table of EXPERIMENTS.md; the per-section ns/op are
//! also emitted to BENCH_hot_path.json at the repo root so the perf
//! trajectory is tracked across PRs.

use ogasched::benchlib::{time_fn, Reporter};
use ogasched::config::Scenario;
use ogasched::oga::dense_ref::DenseOgaState;
use ogasched::oga::gradient::{gradient, GradScratch};
use ogasched::oga::projection::project;
use ogasched::oga::{LearningRate, OgaState};
use ogasched::reward::slot_reward_scratch;
use ogasched::runtime::{default_dir, Manifest, OgaStepExecutor};
use ogasched::traces::synthesize;
use ogasched::utils::rng::Rng;

fn main() {
    let mut rep = Reporter::new("hot_path");
    for (name, mut scenario) in [
        ("small 4x16x4", Scenario::small()),
        ("default 10x128x6", Scenario::default()),
        ("large 100x1024x6", Scenario::large_scale()),
    ] {
        scenario.horizon = 1;
        let p = synthesize(&scenario);
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..p.num_ports())
            .map(|_| if rng.bernoulli(0.7) { 1.0 } else { 0.0 })
            .collect();
        let y: Vec<f64> = (0..p.decision_len()).map(|_| rng.uniform(0.0, 1.0)).collect();

        let mut grad = vec![0.0; p.decision_len()];
        let mut scratch = GradScratch::default();
        rep.record(time_fn(&format!("gradient          {name}"), 3, 50, || {
            gradient(&p, &x, &y, &mut grad, &mut scratch);
            std::hint::black_box(&grad);
        }));
        rep.record(time_fn(&format!("projection(auto)  {name}"), 3, 50, || {
            let mut z = y.clone();
            project(&p, &mut z, 0);
            std::hint::black_box(&z);
        }));
        let mut quota = vec![0.0; p.num_resources];
        rep.record(time_fn(&format!("reward            {name}"), 3, 50, || {
            std::hint::black_box(slot_reward_scratch(&p, &x, &y, &mut quota));
        }));
        let mut state = OgaState::new(&p, LearningRate::Constant(0.5), 0);
        rep.record(time_fn(&format!("native OGA step   {name}"), 3, 50, || {
            state.step(&p, &x);
        }));
        // the seed's dense [L, R, K] step: off-edge re-zeroing, full
        // projection every slot, scoped-thread spawns — the "before" row
        // of the layout comparison
        let mut dense = DenseOgaState::new(&p, 0);
        rep.record(time_fn(&format!("dense-ref OGA step {name}"), 3, 50, || {
            dense.step(&p, &x, 0.5);
        }));
        if let Ok(manifest) = Manifest::load(default_dir()) {
            if let Ok(mut exec) = OgaStepExecutor::new(&manifest, &p) {
                rep.record(time_fn(&format!("PJRT OGA step     {name}"), 3, 50, || {
                    std::hint::black_box(exec.step(&x, 0.5).expect("pjrt"));
                }));
            }
        }
    }
    // machine-readable perf record at the repo root (tracked across PRs)
    rep.write_json(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hot_path.json"));
    rep.finish();
}
