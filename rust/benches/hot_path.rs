//! Hot-path bench: per-slot latency of the whole L3 loop and its parts —
//! gradient, projection, reward, the native edge-major (CSR) OGA step,
//! the seed's dense [L, R, K] step as the before/after baseline, and the
//! PJRT-compiled step (when artifacts are present).  This is the §Perf
//! baseline/after table of EXPERIMENTS.md; the per-section ns/op are
//! also emitted to BENCH_hot_path.json at the repo root so the perf
//! trajectory is tracked across PRs.
//!
//! §Perf-2 adds the *full leader slot* under sparse arrivals (10%
//! Bernoulli) on the large scenario: decide + commit + score + release,
//! once with the incremental ledger driven by the policy's `Touched`
//! reporting and once forced through the full-sweep commit — the
//! before/after pair for the arrival-sparse pipeline.
//!
//! §Perf-5 adds the leaf-kernel rows (sequential reference vs the
//! compiled lane path of `oga::kernels`, f64 and f32) and the sharded
//! oracle-objective rows; build with `--features simd` (nightly) to
//! time the `std::simd` twins under the same row names.

use ogasched::benchlib::{policy_table, time_fn, Reporter};
use ogasched::config::Scenario;
use ogasched::ExecBudget;
use ogasched::coordinator::{ClusterState, ShardPlan, ShardedLeader};
use ogasched::graph::Bipartite;
use ogasched::model::Problem;
use ogasched::oga::dense_ref::DenseOgaState;
use ogasched::oga::gradient::{grad_norm, gradient, GradScratch};
use ogasched::oga::projection::{project, project_instances};
use ogasched::oga::{LearningRate, OgaState};
use ogasched::reward::{slot_reward_kinds, slot_reward_scratch};
use ogasched::runtime::{default_dir, Manifest, OgaStepExecutor};
use ogasched::schedulers::{OgaSched, Policy, Touched};
use ogasched::sim::arrivals::{ArrivalModel, Bernoulli};
use ogasched::traces::synthesize;
use ogasched::utils::rng::Rng;

fn main() {
    let mut rep = Reporter::new("hot_path");
    for (name, mut scenario) in [
        ("small 4x16x4", Scenario::small()),
        ("default 10x128x6", Scenario::default()),
        ("large 100x1024x6", Scenario::large_scale()),
    ] {
        scenario.horizon = 1;
        let p = synthesize(&scenario);
        let kinds = p.kinds();
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..p.num_ports())
            .map(|_| if rng.bernoulli(0.7) { 1.0 } else { 0.0 })
            .collect();
        let y: Vec<f64> = (0..p.decision_len()).map(|_| rng.uniform(0.0, 1.0)).collect();

        let mut grad = vec![0.0; p.decision_len()];
        let mut scratch = GradScratch::default();
        rep.record(time_fn(&format!("gradient          {name}"), 3, 50, || {
            gradient(&p, kinds, &x, &y, &mut grad, &mut scratch);
            std::hint::black_box(&grad);
        }));
        rep.record(time_fn(&format!("projection(auto)  {name}"), 3, 50, || {
            let mut z = y.clone();
            project(&p, &mut z, 0);
            std::hint::black_box(&z);
        }));
        let mut quota = vec![0.0; p.num_resources];
        rep.record(time_fn(&format!("reward            {name}"), 3, 50, || {
            std::hint::black_box(slot_reward_scratch(&p, &x, &y, &mut quota));
        }));
        rep.record(time_fn(&format!("reward(kinds)     {name}"), 3, 50, || {
            std::hint::black_box(slot_reward_kinds(&p, kinds, &x, &y, &mut quota));
        }));
        let mut state = OgaState::new(&p, LearningRate::Constant(0.5), ExecBudget::auto());
        rep.record(time_fn(&format!("native OGA step   {name}"), 3, 50, || {
            state.step(&p, &x);
        }));
        // the seed's dense [L, R, K] step: off-edge re-zeroing, full
        // projection every slot, scoped-thread spawns — the "before" row
        // of the layout comparison
        let mut dense = DenseOgaState::new(&p, 0);
        rep.record(time_fn(&format!("dense-ref OGA step {name}"), 3, 50, || {
            dense.step(&p, &x, 0.5);
        }));
        if let Ok(manifest) = Manifest::load(default_dir()) {
            if let Ok(mut exec) = OgaStepExecutor::new(&manifest, &p) {
                rep.record(time_fn(&format!("PJRT OGA step     {name}"), 3, 50, || {
                    std::hint::black_box(exec.step(&x, 0.5).expect("pjrt"));
                }));
            }
        }
    }

    // ---- §Perf-2: full leader slot, sparse arrivals, large scenario ----
    // decide + commit + score + release per iteration, for both
    // learning-rate schedules; "incr" follows the policy's Touched
    // reporting into commit_instances, "full" forces the |E|·K + R·K
    // full-sweep ledger of PR 1.
    {
        let mut scenario = Scenario::large_scale();
        scenario.horizon = 1;
        let p = synthesize(&scenario);
        let kinds = p.kinds();
        let mut quota = vec![0.0; p.num_resources];

        let make_policy = |schedule: &str| -> OgaSched {
            match schedule {
                "decay" => OgaSched::new(&p, scenario.eta0, scenario.decay, ExecBudget::auto()),
                _ => OgaSched::with_oracle_rate(&p, 10_000, ExecBudget::auto()),
            }
        };
        for schedule in ["decay", "oracle"] {
            // "incr": the §Perf-2 pipeline as the Leader runs it.
            {
                let mut pol = make_policy(schedule);
                let mut arr = Bernoulli::uniform(p.num_ports(), 0.1, 7);
                let mut st = ClusterState::new(&p);
                let mut x = vec![0.0; p.num_ports()];
                let mut y = vec![0.0; p.decision_len()];
                rep.record(time_fn(
                    &format!("leader slot sparse10 {schedule} incr large 100x1024x6"),
                    10,
                    200,
                    || {
                        arr.next(&mut x);
                        pol.decide(&p, &x, &mut y);
                        let report = match pol.touched() {
                            Touched::All => st.commit(&p, &mut y),
                            Touched::Instances(list) => st.commit_instances(&p, &mut y, list),
                        };
                        std::hint::black_box(report);
                        std::hint::black_box(slot_reward_kinds(&p, kinds, &x, &y, &mut quota));
                        st.release();
                    },
                ));
            }
            // "full": the PR 1 slot, emulated stage for stage so the row
            // is comparable with scripts/perf_proxy.py's pr1 pipeline —
            // full |E|·K publish copy, full-sweep commit, per-coordinate
            // scalar reward, eager R·K release copy; the oracle variant
            // additionally pays PR 1's dense decide internals (gradient
            // memset, full-buffer norm, full-buffer ascent).
            {
                let mut pol = make_policy(schedule);
                let lr = LearningRate::Oracle { horizon: 10_000 };
                let mut arr = Bernoulli::uniform(p.num_ports(), 0.1, 7);
                let mut st = ClusterState::new(&p);
                let mut x = vec![0.0; p.num_ports()];
                let mut y = vec![0.0; p.decision_len()];
                let mut y_out = vec![0.0; p.decision_len()];
                let mut remaining = p.capacity.clone();
                let mut grad = vec![0.0; p.decision_len()];
                let mut gs = GradScratch::default();
                let mut dirty: Vec<usize> = Vec::new();
                let mut flags = vec![false; p.num_instances()];
                rep.record(time_fn(
                    &format!("leader slot sparse10 {schedule} full large 100x1024x6"),
                    10,
                    200,
                    || {
                        arr.next(&mut x);
                        if schedule == "decay" {
                            // PR 1's decay decide was already
                            // arrival-sparse internally (fused ascent +
                            // dirty projection) — reuse the policy
                            pol.decide(&p, &x, &mut y);
                        } else {
                            // PR 1's oracle decide: full-buffer two-pass
                            gradient(&p, kinds, &x, &y, &mut grad, &mut gs);
                            let eta = lr.eta(&p, 0, grad_norm(&grad));
                            for i in 0..y.len() {
                                y[i] += eta * grad[i];
                            }
                            dirty.clear();
                            for l in (0..p.num_ports()).filter(|&l| x[l] != 0.0) {
                                for e in p.graph.port_edges(l) {
                                    let r = p.graph.edge_instance[e];
                                    if !flags[r] {
                                        flags[r] = true;
                                        dirty.push(r);
                                    }
                                }
                            }
                            project_instances(&p, &mut y, &dirty, 0);
                            for &r in &dirty {
                                flags[r] = false;
                            }
                        }
                        y_out.copy_from_slice(&y); // PR 1 published the whole tensor
                        std::hint::black_box(st.commit(&p, &mut y_out));
                        std::hint::black_box(slot_reward_scratch(&p, &x, &y_out, &mut quota));
                        st.release();
                        remaining.copy_from_slice(&p.capacity); // PR 1's eager release
                        std::hint::black_box(&remaining);
                    },
                ));
            }
        }
    }

    // ---- §Perf-3: sharded single-slot pipeline, large scenario ----
    // The same sparse10 leader slot driven through the ShardedLeader at
    // 1/2/4/8 shards: decide (per-shard ascent/projection via the bound
    // plan) + sharded commit + sharded reward + release.  shard1 is the
    // single-worker overhead row (plan bound, everything inline); the
    // incr row above is the serial-leader baseline it should match.
    {
        let mut scenario = Scenario::large_scale();
        scenario.horizon = 1;
        let p = synthesize(&scenario);
        let mut occ_rows: Vec<(String, Vec<f64>)> = Vec::new();
        for shards in [1usize, 2, 4, 8] {
            let mut leader = ShardedLeader::new(&p, shards);
            let mut pol = OgaSched::new(&p, scenario.eta0, scenario.decay, ExecBudget::auto());
            pol.bind_shards(leader.plan());
            let mut arr = Bernoulli::uniform(p.num_ports(), 0.1, 7);
            let mut x = vec![0.0; p.num_ports()];
            let mut y = vec![0.0; p.decision_len()];
            rep.record(time_fn(
                &format!("leader slot sparse10 decay shard{shards} large 100x1024x6"),
                10,
                200,
                || {
                    arr.next(&mut x);
                    std::hint::black_box(leader.slot(&mut pol, &x, &mut y));
                },
            ));
            // Per-shard occupancy over everything the timed loop ran:
            // edges touched per (slot, shard) in the reward stage — the
            // LPT-plan skew the static partition leaves under sparse
            // arrivals (work-stealing groundwork; see `figure sparse`
            // for the figure-scale sweep of the same counters).
            let occ = leader.occupancy();
            occ_rows.push((
                format!("shard{shards}"),
                vec![
                    occ.min_or_zero() as f64,
                    occ.mean(),
                    occ.p50() as f64,
                    occ.p99() as f64,
                    occ.max as f64,
                    (occ.count / shards as u64) as f64,
                ],
            ));
        }
        rep.section(
            "per-shard occupancy sparse10 large 100x1024x6 (edges touched per shard-slot)",
            policy_table(&["plan", "min", "mean", "p50", "p99", "max", "slots"], &occ_rows, 1),
        );
    }

    // ---- §Obs: observability overhead on the sharded sparse slot ----
    // The shard4 sparse10 slot re-timed at each obs level: `off` is the
    // shipped default (counters only — one relaxed load + branch past the
    // span sites), `summary` adds span-duration histograms on every
    // slot/phase/shard span, `trace` additionally appends each span to
    // the per-thread rings.  Floats are untouched at every level (see
    // tests/obs_parity.rs); only the row's time may move.  Target:
    // summary within ~2% of off.
    {
        use ogasched::obs;
        let mut scenario = Scenario::large_scale();
        scenario.horizon = 1;
        let p = synthesize(&scenario);
        for level in [obs::ObsLevel::Off, obs::ObsLevel::Summary, obs::ObsLevel::Trace] {
            obs::reset();
            obs::set_level(level);
            let mut leader = ShardedLeader::new(&p, 4);
            let mut pol = OgaSched::new(&p, scenario.eta0, scenario.decay, ExecBudget::auto());
            pol.bind_shards(leader.plan());
            let mut arr = Bernoulli::uniform(p.num_ports(), 0.1, 7);
            let mut x = vec![0.0; p.num_ports()];
            let mut y = vec![0.0; p.decision_len()];
            rep.record(time_fn(
                &format!("leader slot sparse10 decay shard4 obs={} large 100x1024x6", level.name()),
                10,
                200,
                || {
                    arr.next(&mut x);
                    std::hint::black_box(leader.slot(&mut pol, &x, &mut y));
                },
            ));
        }
        obs::set_level(obs::ObsLevel::Off);
        obs::reset();
    }

    // ---- §Perf-4/§Perf-5: sharded Eq. 50 oracle solve, large scenario ----
    // The offline benchmark of Eq. 50 (`regret::solve_oracle`) at
    // 1/2/4/8 shards: per iteration the gradient fill (phase-A port
    // reductions included), ascent, projection AND the objective
    // evaluation fan out over the shard plan while the ‖∇q‖ reduction
    // replays serially — floats identical to shard1
    // (tests/shard_parity.rs), time dropping with shards.
    {
        use ogasched::regret::{arrival_counts, solve_oracle};
        use ogasched::sim::arrivals::record_trajectory;
        let scenario = Scenario::large_scale();
        let p = synthesize(&scenario);
        let mut src = Bernoulli::uniform(p.num_ports(), 0.7, 13);
        let traj = record_trajectory(&mut src, p.num_ports(), 200);
        let counts = arrival_counts(&traj, p.num_ports());
        for shards in [1usize, 2, 4, 8] {
            rep.record(time_fn(
                &format!("solve_oracle 5it oracle shard{shards} large 100x1024x6"),
                2,
                10,
                || {
                    std::hint::black_box(solve_oracle(
                        &p,
                        &counts,
                        5,
                        ExecBudget::shards_only(shards),
                    ));
                },
            ));
        }

        // §Perf-5: the sharded objective evaluation alone — the stage
        // that dominated the PR 4 solve's serial fraction (~47% at this
        // scale).  Dense counts (every port arrived), merge replayed
        // serially in ascending port order, floats identical across
        // rows.
        {
            use ogasched::reward::{slot_reward_ports_sharded, PortRewardScratch};
            let mut rng = Rng::new(17);
            let y: Vec<f64> =
                (0..p.decision_len()).map(|_| rng.uniform(0.0, 1.0)).collect();
            let arrived: Vec<usize> =
                (0..p.num_ports()).filter(|&l| counts[l] != 0.0).collect();
            let mut scratch = PortRewardScratch::default();
            for shards in [1usize, 2, 4, 8] {
                rep.record(time_fn(
                    &format!("oracle objective shard{shards} large 100x1024x6"),
                    5,
                    100,
                    || {
                        std::hint::black_box(slot_reward_ports_sharded(
                            &p,
                            p.kinds(),
                            &counts,
                            &y,
                            &arrived,
                            shards,
                            &mut scratch,
                        ));
                    },
                ));
            }
        }
    }

    // ---- §Perf-5: leaf-kernel rows, scalar-vs-lane ----
    // `ref` is the kept sequential reference (`oga::kernels::*_ref`);
    // `lane` is whatever the build compiled — the scalar lane-tree path
    // on stable, the `std::simd` twin under `--features simd` (both
    // produce the same floats; only the row's time moves).  `lane-f32`
    // is the artifact-path f32 calculus at 8 lanes.
    {
        use ogasched::oga::kernels;
        use ogasched::oga::utilities::UtilityKind;
        const N: usize = 4096;
        let mut rng = Rng::new(29);
        let y: Vec<f64> = (0..N).map(|_| rng.uniform(0.0, 3.0)).collect();
        let alpha: Vec<f64> = (0..N).map(|_| rng.uniform(0.5, 2.0)).collect();
        let y32: Vec<f32> = y.iter().map(|&v| v as f32).collect();
        let alpha32: Vec<f32> = alpha.iter().map(|&v| v as f32).collect();
        let mut out = vec![0.0f64; N];
        let mut out32 = vec![0.0f32; N];
        for kind in UtilityKind::ALL {
            rep.record(time_fn(
                &format!("kernel value_sum ref {} n=4096", kind.name()),
                20,
                400,
                || {
                    std::hint::black_box(kernels::value_sum_ref(kind, &y, &alpha));
                },
            ));
            rep.record(time_fn(
                &format!("kernel value_sum lane {} n=4096", kind.name()),
                20,
                400,
                || {
                    std::hint::black_box(kind.value_sum(&y, &alpha));
                },
            ));
            rep.record(time_fn(
                &format!("kernel grad_into ref {} n=4096", kind.name()),
                20,
                400,
                || {
                    kernels::grad_into_ref(kind, &y, &alpha, 0.75, &mut out);
                    std::hint::black_box(&out);
                },
            ));
            rep.record(time_fn(
                &format!("kernel grad_into lane {} n=4096", kind.name()),
                20,
                400,
                || {
                    kind.grad_into(&y, &alpha, 0.75, &mut out);
                    std::hint::black_box(&out);
                },
            ));
            rep.record(time_fn(
                &format!("kernel value_sum ref-f32 {} n=4096", kind.name()),
                20,
                400,
                || {
                    std::hint::black_box(kernels::value_sum_f32_ref(kind, &y32, &alpha32));
                },
            ));
            rep.record(time_fn(
                &format!("kernel value_sum lane-f32 {} n=4096", kind.name()),
                20,
                400,
                || {
                    std::hint::black_box(kernels::value_sum_f32(kind, &y32, &alpha32));
                },
            ));
            rep.record(time_fn(
                &format!("kernel grad_into lane-f32 {} n=4096", kind.name()),
                20,
                400,
                || {
                    kernels::grad_into_f32(kind, &y32, &alpha32, 0.75, &mut out32);
                    std::hint::black_box(&out32);
                },
            ));
        }
    }

    // ---- §Perf-4: lineup under a hierarchical budget, default scenario ----
    // The whole five-policy sweep at the three splits of a 4-worker
    // budget (plus the serial floor): runs x shards compose — 1x4 is a
    // serial lineup of 4-shard leaders, 4x1 is four concurrent serial
    // leaders, 2x2 is both at once.  Results are bit-identical across
    // rows; only wall clock moves.
    {
        use ogasched::coordinator::run_lineup;
        use ogasched::schedulers::paper_lineup;
        let mut scenario = Scenario::default();
        scenario.horizon = 50;
        let p = synthesize(&scenario);
        for (label, budget) in [
            ("serial", ExecBudget::serial()),
            ("1x4", ExecBudget::split(1, 4)),
            ("2x2", ExecBudget::split(2, 2)),
            ("4x1", ExecBudget::split(4, 1)),
        ] {
            rep.record(time_fn(
                &format!("run_lineup 5pol h50 budget {label} default 10x128x6"),
                1,
                5,
                || {
                    let mut lineup =
                        paper_lineup(&p, scenario.eta0, scenario.decay, budget);
                    let results = run_lineup(
                        &p,
                        &mut lineup,
                        || {
                            Box::new(Bernoulli::uniform(
                                p.num_ports(),
                                scenario.arrival_prob,
                                scenario.seed ^ 0xA5A5,
                            ))
                        },
                        scenario.horizon,
                        budget,
                    );
                    std::hint::black_box(results);
                },
            ));
        }
    }

    // ---- §Churn: one topology edition, incremental vs rebuild ----
    // Each iteration produces two editions (instance fails, then
    // recovers).  "incremental" mutates the problem in place
    // (remove/restore + reindex) and refreshes the shard plan under the
    // re-plan epoch rule; "rebuild" reconstructs Problem + LPT plan
    // from scratch for each edition — the two churn-parity arms, timed.
    {
        let mut scenario = Scenario::large_scale();
        scenario.horizon = 1;
        let p = synthesize(&scenario);
        let shards = 8usize;
        let e0: Vec<(usize, usize)> = (0..p.num_edges())
            .map(|e| (p.graph.edge_port[e], p.graph.edge_instance[e]))
            .collect();
        let r_fail = 7usize;
        let back: Vec<(usize, usize)> =
            e0.iter().copied().filter(|&(_, r)| r == r_fail).collect();
        let live: Vec<(usize, usize)> =
            e0.iter().copied().filter(|&(_, r)| r != r_fail).collect();
        {
            let mut cur = p.clone();
            let plan = ShardPlan::build(&cur, shards);
            rep.record(time_fn("churn epoch incremental large 100x1024x6", 3, 30, || {
                cur.remove_instance_edges(r_fail).expect("in range");
                let refreshed = plan.refresh(&cur).expect("same R");
                std::hint::black_box(refreshed.imbalance());
                cur.restore_edges(&back).expect("in range");
                std::hint::black_box(plan.refresh(&cur).expect("same R"));
            }));
        }
        rep.record(time_fn("churn epoch rebuild large 100x1024x6", 3, 30, || {
            for edges in [&live, &e0] {
                let edition = Problem::new(
                    Bipartite::from_edges(p.num_ports(), p.num_instances(), edges),
                    p.num_resources,
                    p.demand.clone(),
                    p.capacity.clone(),
                    p.alpha.clone(),
                    p.kind.clone(),
                    p.beta.clone(),
                );
                std::hint::black_box(ShardPlan::build(&edition, shards));
            }
        }));
    }

    // ---- §Recover: checkpointed execution + kill-and-resume, default ----
    // Overhead story first: the same 50-slot OGASCHED run uninterrupted
    // (`nockpt`), then through the resilient driver at checkpoint epochs
    // {1, 5, 17} with no injected faults — the gap is pure freeze cost
    // (snapshot serialization amortized over epoch slots; results are
    // bitwise-identical by the recovery-parity contract).  The `kills`
    // row injects process kills on top of epoch 5, so it additionally
    // pays thaw + replay of the slots since the last checkpoint.
    {
        use ogasched::sim::checkpoint::run_resilient_scenario;
        use ogasched::sim::run_on_problem;
        let mut scenario = Scenario::default();
        scenario.horizon = 50;
        let p = synthesize(&scenario);
        rep.record(time_fn("resilient run h50 nockpt default 10x128x6", 1, 5, || {
            let mut pol =
                OgaSched::new(&p, scenario.eta0, scenario.decay, ExecBudget::auto());
            std::hint::black_box(run_on_problem(&scenario, &p, &mut pol));
        }));
        for epoch in [1usize, 5, 17] {
            let mut s = scenario.clone();
            s.recovery.checkpoint_epoch = epoch;
            rep.record(time_fn(
                &format!("resilient run h50 epoch{epoch} default 10x128x6"),
                1,
                5,
                || {
                    let mut pol =
                        OgaSched::new(&p, s.eta0, s.decay, ExecBudget::auto());
                    std::hint::black_box(
                        run_resilient_scenario(&s, &mut pol, false).expect("resilient"),
                    );
                },
            ));
        }
        {
            let mut s = scenario.clone();
            s.recovery.checkpoint_epoch = 5;
            s.recovery.kill_rate = 0.04;
            s.recovery.seed = 11;
            rep.record(time_fn(
                "resilient run h50 epoch5 kills default 10x128x6",
                1,
                5,
                || {
                    let mut pol =
                        OgaSched::new(&p, s.eta0, s.decay, ExecBudget::auto());
                    std::hint::black_box(
                        run_resilient_scenario(&s, &mut pol, false).expect("resilient"),
                    );
                },
            ));
        }
    }

    // ---- §SStore: durable checkpoint chain — persist + fallback thaw ----
    // The freeze+persist pair first: the same epoch-5 resilient run
    // with the chain held in memory vs persisted to disk (write-temp +
    // flush + atomic rename per blob; a fresh directory per iteration).
    // Then the recovery walk: one kill late in the run against a chain
    // whose newest {0, 1, 3} blobs are torn — the fallback rows
    // additionally pay the rejected CRC walks plus the longer replay
    // from the older restore point.  All rows are bitwise-equal to the
    // uninterrupted run by the §SStore parity contract.
    {
        use ogasched::config::{FaultConfig, RecoveryConfig};
        use ogasched::sim::checkpoint::run_resilient_with_store;
        use ogasched::sim::faults::{ExecFaultPlan, FaultPlan};
        use ogasched::sim::store::BlobStore;
        use std::sync::atomic::{AtomicU64, Ordering};

        let mut scenario = Scenario::default();
        scenario.horizon = 50;
        let p = synthesize(&scenario);
        let fcfg = FaultConfig::default();
        let plan = FaultPlan::for_problem(&p, scenario.horizon, &fcfg);
        let rcfg = RecoveryConfig {
            checkpoint_epoch: 5,
            chain_depth: 5,
            ..RecoveryConfig::default()
        };
        let run = |store: &mut BlobStore, exec: &ExecFaultPlan| {
            let mut pol =
                OgaSched::new(&p, scenario.eta0, scenario.decay, ExecBudget::auto());
            pol.reset(&p);
            let mut arr = Bernoulli::uniform(
                p.num_ports(),
                scenario.arrival_prob,
                scenario.seed ^ 0xA5A5,
            );
            std::hint::black_box(
                run_resilient_with_store(
                    &p, &mut pol, &mut arr, scenario.horizon, 1, &plan, &fcfg, false,
                    &rcfg, exec, store,
                )
                .expect("sstore bench"),
            );
        };
        let quiet = ExecFaultPlan::default();
        rep.record(time_fn("sstore freeze+put mem h50 epoch5 default 10x128x6", 1, 5, || {
            let mut store = BlobStore::memory(rcfg.chain_depth);
            run(&mut store, &quiet);
        }));
        let root = std::env::temp_dir()
            .join(format!("ogasched-sstore-bench-{}", std::process::id()));
        let iter = AtomicU64::new(0);
        rep.record(time_fn("sstore freeze+put disk h50 epoch5 default 10x128x6", 1, 5, || {
            let dir = root.join(format!("i{}", iter.fetch_add(1, Ordering::Relaxed)));
            let mut store = BlobStore::open(&dir, rcfg.chain_depth).expect("open store");
            run(&mut store, &quiet);
        }));
        let _ = std::fs::remove_dir_all(&root);
        for (label, torn) in [
            ("valid", &[][..]),
            ("fallback1", &[40u64][..]),
            ("fallback3", &[30u64, 35, 40][..]),
        ] {
            let mut exec = ExecFaultPlan { kills: vec![41], ..ExecFaultPlan::default() };
            for &s in torn {
                exec.torn_writes.insert(s, 0xBEEF + s);
            }
            rep.record(time_fn(
                &format!("sstore thaw {label} h50 epoch5 default 10x128x6"),
                1,
                5,
                || {
                    let mut store = BlobStore::memory(rcfg.chain_depth);
                    run(&mut store, &exec);
                },
            ));
        }
    }

    // ---- §SPerf-9: streaming ingest + overlapped slot pipeline ----
    // Queue-op floor first (push + ticketed k-way-merge pop per event,
    // single producer), then the full streaming slot, then the
    // pipeline pair: the same 40-slot OGASCHED run driven through
    // `run_pipeline` lockstep (the bitwise reference) and overlapped
    // (slot t+1 decide concurrent with slot t commit + reward).  The
    // pair is bit-identical by the pipeline-parity contract; the gap is
    // the Amdahl overlap win minus the handoff copy.  `ogasched serve`
    // sweeps the same pair at figure scale into BENCH_throughput.json.
    {
        use ogasched::coordinator::{run_pipeline, PipelineMode};
        use ogasched::sim::ingest::{IngestQueue, StreamArrivals, StreamParams};
        {
            let q = IngestQueue::new(1, 4096, true);
            let prod = q.producer(0);
            rep.record(time_fn("ingest queue push+pop 1prod n=1024", 10, 400, || {
                for i in 0..1024u32 {
                    prod.push(i & 63, 1.0);
                }
                while let Some(ev) = q.pop() {
                    std::hint::black_box(ev);
                }
            }));
        }
        {
            let scenario = Scenario::default();
            let p = synthesize(&scenario);
            let mut arr =
                StreamArrivals::new(p.num_ports(), StreamParams::default(), 41);
            let mut x = vec![0.0; p.num_ports()];
            rep.record(time_fn("stream next batch32 default 10x128x6", 10, 400, || {
                arr.next(&mut x);
                std::hint::black_box(&x);
            }));
        }
        let mut scenario = Scenario::default();
        scenario.horizon = 40;
        let p = synthesize(&scenario);
        for batch in [32usize, 128] {
            for mode in [PipelineMode::Lockstep, PipelineMode::Overlapped] {
                rep.record(time_fn(
                    &format!(
                        "pipeline h40 {} batch{batch} shard4 default 10x128x6",
                        mode.name()
                    ),
                    1,
                    5,
                    || {
                        let mut leader = ShardedLeader::new(&p, 4);
                        let mut pol = OgaSched::new(
                            &p,
                            scenario.eta0,
                            scenario.decay,
                            ExecBudget::auto(),
                        );
                        let params =
                            StreamParams { batch_events: batch, ..StreamParams::default() };
                        let mut arr =
                            StreamArrivals::new(p.num_ports(), params, scenario.seed ^ 0x1A57);
                        std::hint::black_box(run_pipeline(
                            &mut leader,
                            &mut pol,
                            &mut arr,
                            scenario.horizon,
                            mode,
                        ));
                    },
                ));
            }
        }
    }

    // machine-readable perf record at the repo root (tracked across PRs)
    rep.write_json(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hot_path.json"));
    rep.finish();
}
