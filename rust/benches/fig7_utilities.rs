//! Bench: regenerate Fig. 7 (cumulative rewards per utility family).

use ogasched::benchlib::{scaled, time_fn, Reporter};
use ogasched::figures::fig7;

fn main() {
    let mut rep = Reporter::new("fig7_utilities");
    let t = scaled(2000, 100);
    rep.record(time_fn(&format!("fig7 sweep T={t}"), 0, 1, || {
        std::hint::black_box(&fig7::run(t));
    }));
    rep.section("Fig. 7 output", fig7::run(t));
    rep.finish();
}
