//! Ablation bench: Algorithm 1's fast projection.
//!
//! Compares (a) the breakpoint-scan channel projector against the
//! bisection reference, (b) serial vs parallel full-tensor projection
//! (the "for each (r,k) in parallel" claim), across problem scales.

use ogasched::benchlib::{time_fn, Reporter};
use ogasched::config::Scenario;
use ogasched::oga::projection::{
    project, project_channel, project_channel_bisect, project_serial,
};
use ogasched::traces::synthesize;
use ogasched::utils::rng::Rng;

fn main() {
    let mut rep = Reporter::new("ablation_projection");

    // (a) single-channel projector vs bisection reference
    let mut rng = Rng::new(7);
    for n in [8usize, 64, 512] {
        let vals: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 6.0)).collect();
        let caps: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 3.0)).collect();
        let cap = 0.3 * caps.iter().sum::<f64>();
        let mut events = Vec::new();
        rep.record(time_fn(&format!("channel event-sweep    n={n}"), 10, 200, || {
            let mut v = vals.clone();
            std::hint::black_box(project_channel(&mut v, &caps, cap, &mut events));
        }));
        rep.record(time_fn(&format!("channel bisection-ref  n={n}"), 10, 200, || {
            let mut v = vals.clone();
            std::hint::black_box(project_channel_bisect(&mut v, &caps, cap));
        }));
    }

    // (b) full-tensor projection: serial vs parallel
    for (name, mut scenario) in [
        ("default 10x128x6", Scenario::default()),
        ("large 100x1024x6", Scenario::large_scale()),
    ] {
        scenario.horizon = 1;
        let p = synthesize(&scenario);
        let mut rng = Rng::new(3);
        let z: Vec<f64> = (0..p.decision_len()).map(|_| rng.uniform(-1.0, 8.0)).collect();
        rep.record(time_fn(&format!("project serial   {name}"), 2, 20, || {
            let mut zz = z.clone();
            project_serial(&p, &mut zz);
            std::hint::black_box(&zz);
        }));
        for workers in [2usize, 4, 8] {
            rep.record(time_fn(&format!("project par({workers})  {name}"), 2, 20, || {
                let mut zz = z.clone();
                project(&p, &mut zz, workers);
                std::hint::black_box(&zz);
            }));
        }
    }
    rep.finish();
}
