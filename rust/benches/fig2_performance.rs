//! Bench: regenerate Fig. 2 (performance verification) and time the
//! underlying lineup run.  OGASCHED_BENCH_SCALE shrinks T for CI.

use ogasched::benchlib::{scaled, time_fn, Reporter};
use ogasched::figures::fig2;

fn main() {
    let mut rep = Reporter::new("fig2_performance");
    let t = scaled(8000, 200);
    rep.record(time_fn(&format!("fig2 lineup T={t}"), 0, 1, || {
        let out = fig2::run(t);
        std::hint::black_box(&out);
    }));
    rep.section("Fig. 2 output", fig2::run(t));
    rep.finish();
}
