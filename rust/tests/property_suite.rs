//! Cross-module randomized property suite (hand-rolled harness in
//! utils::prop): the invariants the paper's correctness rests on, hit
//! with random problems rather than fixed fixtures.

use ogasched::config::{GraphSpec, Scenario};
use ogasched::ExecBudget;
use ogasched::model::KindIndex;
use ogasched::oga::gradient::{gradient, GradScratch};
use ogasched::oga::projection::project;
use ogasched::oga::utilities::{UtilityKind, UtilityMix};
use ogasched::oga::{LearningRate, OgaState};
use ogasched::reward::slot_reward;
use ogasched::schedulers::{paper_lineup, Policy};
use ogasched::traces::synthesize;
use ogasched::utils::prop::{check, ensure, Size};
use ogasched::utils::rng::Rng;

fn random_scenario(rng: &mut Rng, size: Size) -> Scenario {
    let mut s = Scenario::small();
    s.num_ports = rng.range(1, size.dim(8, 1));
    s.num_instances = rng.range(1, size.dim(24, 1));
    s.num_resources = rng.range(1, size.dim(6, 1));
    s.contention = rng.uniform(0.5, 15.0);
    s.arrival_prob = rng.uniform(0.1, 1.0);
    s.seed = rng.next_u64();
    s.graph = match rng.below(3) {
        0 => GraphSpec::Full,
        1 => GraphSpec::RightRegular(rng.range(1, s.num_ports)),
        _ => GraphSpec::Density(rng.uniform(1.0, s.num_ports as f64)),
    };
    s.utility_mix = match rng.below(3) {
        0 => UtilityMix::Mixed,
        1 => UtilityMix::All(UtilityKind::Log),
        _ => UtilityMix::All(UtilityKind::Linear),
    };
    s
}

#[test]
fn every_policy_feasible_on_random_problems() {
    check("policies-feasible", 40, |rng, size| {
        let s = random_scenario(rng, size);
        let p = synthesize(&s);
        let mut y = vec![0.0; p.decision_len()];
        for mut policy in paper_lineup(&p, 5.0, 0.999, ExecBudget::serial()) {
            for _ in 0..5 {
                let x: Vec<f64> = (0..p.num_ports())
                    .map(|_| if rng.bernoulli(s.arrival_prob) { 1.0 } else { 0.0 })
                    .collect();
                policy.decide(&p, &x, &mut y);
                if let Err(e) = p.check_feasible(&y, 1e-6) {
                    return Err(format!("{} on {:?}: {e}", policy.name(), s.graph));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn projection_never_lowers_capped_objective() {
    // For the projected point v = P(z): moving from v toward z (the
    // unconstrained ascent target) must exit Y or stay equal — i.e. v is
    // the closest feasible point along that segment.
    check("projection-segment-optimal", 60, |rng, size| {
        let s = random_scenario(rng, size);
        let p = synthesize(&s);
        let z: Vec<f64> = (0..p.decision_len()).map(|_| rng.uniform(-1.0, 6.0)).collect();
        let mut v = z.clone();
        project(&p, &mut v, 1);
        p.check_feasible(&v, 1e-7).map_err(|e| e.to_string())?;
        // any strict step from v toward z leaves Y unless v == z (on-edge)
        let step = 0.5;
        let mut w = v.clone();
        let mut moved = false;
        for l in 0..p.num_ports() {
            for &r in &p.graph.ports_to_instances[l] {
                for k in 0..p.num_resources {
                    let i = p.idx(l, r, k);
                    if (z[i] - v[i]).abs() > 1e-9 {
                        w[i] = v[i] + step * (z[i] - v[i]);
                        moved = true;
                    }
                }
            }
        }
        if moved {
            ensure(p.check_feasible(&w, 1e-7).is_err(), || {
                "a point strictly between P(z) and z is still feasible — \
                 projection was not tight"
                    .to_string()
            })?;
        }
        Ok(())
    });
}

#[test]
fn gradient_is_ascent_direction() {
    // At interior points, an infinitesimal step along ∇q must not lower q.
    check("gradient-ascent-direction", 40, |rng, size| {
        let s = random_scenario(rng, size);
        let p = synthesize(&s);
        let x: Vec<f64> = (0..p.num_ports()).map(|_| 1.0).collect();
        // strictly interior point: tiny fractions of demand
        let mut y = vec![0.0; p.decision_len()];
        for l in 0..p.num_ports() {
            for &r in &p.graph.ports_to_instances[l] {
                for k in 0..p.num_resources {
                    y[p.idx(l, r, k)] = 0.01 * p.demand_at(l, k) * rng.f64();
                }
            }
        }
        let mut g = vec![0.0; p.decision_len()];
        let kinds = KindIndex::build(&p);
        gradient(&p, &kinds, &x, &y, &mut g, &mut GradScratch::default());
        let before = slot_reward(&p, &x, &y).q;
        let eps = 1e-7;
        for i in 0..y.len() {
            y[i] += eps * g[i];
        }
        let after = slot_reward(&p, &x, &y).q;
        ensure(after >= before - 1e-9, || {
            format!("gradient step lowered reward: {before} -> {after}")
        })
    });
}

#[test]
fn oga_trajectory_stays_feasible_under_any_learning_rate() {
    check("oga-feasible-any-lr", 30, |rng, size| {
        let s = random_scenario(rng, size);
        let p = synthesize(&s);
        let lr = match rng.below(3) {
            0 => LearningRate::Constant(rng.uniform(0.01, 100.0)),
            1 => LearningRate::Decay {
                eta0: rng.uniform(0.1, 200.0),
                lambda: rng.uniform(0.9, 1.01),
            },
            _ => LearningRate::Oracle { horizon: rng.range(10, 500) },
        };
        let mut state = OgaState::new(&p, lr, ExecBudget::serial());
        for _ in 0..8 {
            let x: Vec<f64> = (0..p.num_ports())
                .map(|_| if rng.bernoulli(0.7) { 1.0 } else { 0.0 })
                .collect();
            state.step(&p, &x);
            p.check_feasible(&state.y, 1e-6).map_err(|e| format!("{lr:?}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn reward_decomposition_consistent() {
    // q == gain - penalty for every policy decision on random problems.
    check("reward-decomposition", 40, |rng, size| {
        let s = random_scenario(rng, size);
        let p = synthesize(&s);
        let x: Vec<f64> = (0..p.num_ports())
            .map(|_| if rng.bernoulli(0.8) { 1.0 } else { 0.0 })
            .collect();
        let mut policy = paper_lineup(&p, 5.0, 0.999, ExecBudget::serial())
            .into_iter()
            .nth(rng.below(5))
            .unwrap();
        let mut y = vec![0.0; p.decision_len()];
        policy.decide(&p, &x, &mut y);
        let r = slot_reward(&p, &x, &y);
        ensure((r.q - (r.gain - r.penalty)).abs() < 1e-9, || {
            format!("q {} != gain {} - penalty {}", r.q, r.gain, r.penalty)
        })?;
        ensure(r.penalty >= -1e-12, || format!("negative penalty {}", r.penalty))
    });
}
