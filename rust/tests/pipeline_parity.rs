//! Pipeline parity (§SPerf-9): the **overlapped slot pipeline** — slot
//! t+1's decide running concurrently with slot t's commit + reward
//! merge on a committer thread — must reproduce the **lockstep**
//! schedule bit for bit: every slot record (q, gain, penalty,
//! arrivals), the cumulative reward, the final ledger (remaining
//! capacity per (r, k)) and the final decision tensor, across the
//! policy lineup × worker budgets {1, 2, 4} × arrival sources
//! (Bernoulli and the lock-free streaming-ingest queue at several
//! batch shapes).
//!
//! The suite also pins the **kill-and-resume composition**: a run over
//! the same ingest stream that is killed mid-flight and thawed from a
//! checkpoint carrying the v2 ingest cursor/batch-state section must
//! land on the same bits as the uninterrupted overlapped pipeline.
//!
//! The CI matrix re-runs this suite under several `PALLAS_WORKERS`
//! budgets × batch shapes (`PIPELINE_BATCH_SHAPES`) with
//! `--test-threads=1`.

use ogasched::config::{FaultConfig, RecoveryConfig};
use ogasched::coordinator::{run_pipeline, PipelineMode, PipelineRun, ShardedLeader};
use ogasched::graph::Bipartite;
use ogasched::model::Problem;
use ogasched::oga::utilities::UtilityKind;
use ogasched::schedulers::{
    BinPacking, Drf, Fairness, OgaMirror, OgaSched, Policy, RandomAlloc, Spreading,
};
use ogasched::sim::arrivals::{ArrivalModel, Bernoulli};
use ogasched::sim::checkpoint::run_resilient;
use ogasched::sim::faults::{ExecFaultPlan, FaultPlan};
use ogasched::sim::ingest::{StreamArrivals, StreamParams};
use ogasched::utils::prop::{check_seeded, ensure, Size};
use ogasched::utils::rng::Rng;
use ogasched::ExecBudget;

const SHARD_COUNTS: [usize; 3] = [1, 2, 4];

/// Batch shapes for the streaming source; the CI pipeline-parity job
/// sweeps this via the environment (comma-separated `batch_events`).
fn batch_shapes() -> Vec<usize> {
    match std::env::var("PIPELINE_BATCH_SHAPES") {
        Ok(v) => v
            .split(',')
            .map(|s| s.trim().parse().expect("PIPELINE_BATCH_SHAPES: bad integer"))
            .collect(),
        Err(_) => vec![8, 32],
    }
}

fn base_seed() -> u64 {
    std::env::var("PIPELINE_PARITY_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x51_9E)
}

fn random_problem(rng: &mut Rng, size: Size) -> Problem {
    let l_n = rng.range(1, size.dim(6, 1));
    let r_n = rng.range(2, size.dim(16, 2).max(3));
    let k_n = rng.range(1, size.dim(4, 1));
    let p = rng.uniform(0.2, 0.9);
    let mut edges = Vec::new();
    for l in 0..l_n {
        for r in 0..r_n {
            if rng.bernoulli(p) {
                edges.push((l, r));
            }
        }
    }
    let graph = Bipartite::from_edges(l_n, r_n, &edges);
    Problem::new(
        graph,
        k_n,
        (0..l_n * k_n).map(|_| rng.uniform(0.2, 3.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 4.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 2.0)).collect(),
        (0..r_n * k_n).map(|_| UtilityKind::ALL[rng.below(4)]).collect(),
        (0..k_n).map(|_| rng.uniform(0.1, 0.8)).collect(),
    )
}

fn make_policy(p: &Problem, i: usize, seed: u64) -> (&'static str, Box<dyn Policy + Send>) {
    match i {
        0 => ("oga-reactive", Box::new(OgaSched::new(p, 2.0, 0.999, ExecBudget::auto()))),
        1 => ("oga-reservation", Box::new(OgaSched::reservation(p, 2.0, 0.999, ExecBudget::auto()))),
        2 => ("oga-mirror", Box::new(OgaMirror::new(p, 2.0, 0.999, ExecBudget::auto()))),
        3 => ("drf", Box::new(Drf::new())),
        4 => ("fairness", Box::new(Fairness::new())),
        5 => ("binpacking", Box::new(BinPacking::new())),
        6 => ("spreading", Box::new(Spreading::new())),
        _ => ("random", Box::new(RandomAlloc::new(seed))),
    }
}

const N_POLICIES: usize = 8;

/// An arrival source the matrix can rebuild identically per run: the
/// dense Bernoulli reference model, or the streaming-ingest queue at a
/// given batch shape (same-thread producer, lossless by construction).
#[derive(Clone, Copy)]
enum Source {
    Bernoulli { rho: f64, seed: u64 },
    Stream { batch_events: usize, seed: u64 },
}

impl Source {
    fn build(self, num_ports: usize) -> Box<dyn ArrivalModel> {
        match self {
            Source::Bernoulli { rho, seed } => {
                Box::new(Bernoulli::uniform(num_ports, rho, seed))
            }
            Source::Stream { batch_events, seed } => {
                let params = StreamParams { batch_events, ..StreamParams::default() };
                Box::new(StreamArrivals::new(num_ports, params, seed))
            }
        }
    }

    fn name(self) -> String {
        match self {
            Source::Bernoulli { .. } => "bernoulli".into(),
            Source::Stream { batch_events, .. } => format!("stream/b{batch_events}"),
        }
    }
}

/// One full pipeline run: the result, the final decision tensor, and
/// the flattened remaining-capacity grid.
fn run_once(
    p: &Problem,
    policy_ix: usize,
    policy_seed: u64,
    source: Source,
    horizon: usize,
    shards: usize,
    mode: PipelineMode,
) -> (PipelineRun, Vec<f64>) {
    let (_, mut pol) = make_policy(p, policy_ix, policy_seed);
    pol.reset(p);
    let mut arr = source.build(p.num_ports());
    let mut leader = ShardedLeader::new(p, shards);
    let out = run_pipeline(&mut leader, pol.as_mut(), arr.as_mut(), horizon, mode);
    let mut remaining = Vec::new();
    for r in 0..p.num_instances() {
        for k in 0..p.num_resources {
            remaining.push(leader.state().remaining_at(r, k));
        }
    }
    (out, remaining)
}

fn compare(
    ctx: &str,
    got: &(PipelineRun, Vec<f64>),
    want: &(PipelineRun, Vec<f64>),
) -> Result<(), String> {
    ensure(
        got.0.result.cumulative_reward == want.0.result.cumulative_reward,
        || {
            format!(
                "{ctx}: cumulative {} vs {}",
                got.0.result.cumulative_reward, want.0.result.cumulative_reward
            )
        },
    )?;
    ensure(got.0.result.clamped_total == want.0.result.clamped_total, || {
        format!("{ctx}: clamped totals diverged")
    })?;
    ensure(got.0.result.records == want.0.result.records, || {
        let at = got
            .0
            .result
            .records
            .iter()
            .zip(&want.0.result.records)
            .position(|(a, b)| a != b);
        format!("{ctx}: slot records diverged (first at {at:?})")
    })?;
    ensure(got.0.y == want.0.y, || format!("{ctx}: decision tensors diverged"))?;
    ensure(got.1 == want.1, || format!("{ctx}: ledgers diverged"))?;
    Ok(())
}

#[test]
fn overlapped_matches_lockstep_bitwise_across_the_matrix() {
    check_seeded("pipeline-parity", base_seed(), 3, |rng, size| {
        let p = random_problem(rng, size);
        let horizon = 32;
        let policy_seed = rng.below(1 << 30) as u64;
        let arrival_seed = rng.below(1 << 30) as u64;
        let mut sources = vec![Source::Bernoulli { rho: 0.6, seed: arrival_seed }];
        for shape in batch_shapes() {
            sources.push(Source::Stream { batch_events: shape, seed: arrival_seed ^ 0x57 });
        }
        for i in 0..N_POLICIES {
            for &src in &sources {
                let reference =
                    run_once(&p, i, policy_seed, src, horizon, 1, PipelineMode::Lockstep);
                ensure(reference.0.result.records.len() == horizon, || {
                    format!("policy {i}: expected {horizon} records")
                })?;
                let name = make_policy(&p, i, policy_seed).0;
                for &shards in &SHARD_COUNTS {
                    let got = run_once(
                        &p, i, policy_seed, src, horizon, shards, PipelineMode::Overlapped,
                    );
                    compare(
                        &format!("{name} {} overlapped shards={shards}", src.name()),
                        &got,
                        &reference,
                    )?;
                }
                // run_lockstep at a non-trivial budget is the same
                // machinery on a different shard plan — still bitwise
                let got =
                    run_once(&p, i, policy_seed, src, horizon, 4, PipelineMode::Lockstep);
                compare(&format!("{name} {} lockstep shards=4", src.name()), &got, &reference)?;
            }
        }
        Ok(())
    });
}

#[test]
fn killed_and_resumed_ingest_run_matches_the_overlapped_pipeline() {
    // the three-way pin: uninterrupted lockstep ≡ uninterrupted
    // overlapped ≡ killed-and-resumed (checkpoints carry the v2 ingest
    // cursor/batch-state section; kills discard the live queue, the
    // restored RNG regenerates it)
    let mut rng = Rng::new(base_seed() ^ 0x1E57);
    let p = random_problem(&mut rng, Size { scale: 1.0 });
    let horizon = 36;
    let shards = 2;
    let cfg = FaultConfig::default(); // no churn: isolate the ingest path
    let plan = FaultPlan::for_problem(&p, horizon, &cfg);
    assert!(plan.is_empty(), "zero-rate fault plan must be empty");
    for shape in batch_shapes() {
        let src = Source::Stream { batch_events: shape, seed: 0xFEED ^ shape as u64 };
        for policy_ix in [0usize, 4] {
            let (name, _) = make_policy(&p, policy_ix, 7);
            let ctx = format!("{name} b={shape}");
            let reference =
                run_once(&p, policy_ix, 7, src, horizon, shards, PipelineMode::Lockstep);
            let over =
                run_once(&p, policy_ix, 7, src, horizon, shards, PipelineMode::Overlapped);
            compare(&format!("{ctx} overlapped"), &over, &reference).unwrap();

            let rcfg = RecoveryConfig {
                checkpoint_epoch: 4,
                seed: 11 + shape as u64,
                ..RecoveryConfig::default()
            };
            let exec =
                ExecFaultPlan { kills: vec![5, 13, 29], ..ExecFaultPlan::default() };
            let (_, mut pol) = make_policy(&p, policy_ix, 7);
            pol.reset(&p);
            let mut arr = src.build(p.num_ports());
            let out = run_resilient(
                &p, pol.as_mut(), arr.as_mut(), horizon, shards, &plan, &cfg, false,
                &rcfg, &exec,
            )
            .unwrap_or_else(|e| panic!("{ctx}: resilient run failed: {e}"));
            assert_eq!(out.kills, 3, "{ctx}: kills not all taken");
            assert!(out.checkpoints_written > 0, "{ctx}: no checkpoint written");
            assert_eq!(
                out.churn.result.records, reference.0.result.records,
                "{ctx}: killed-and-resumed records diverged from the pipeline"
            );
            assert_eq!(
                out.churn.result.cumulative_reward, reference.0.result.cumulative_reward,
                "{ctx}: cumulative reward diverged"
            );
            for r in 0..p.num_instances() {
                for k in 0..p.num_resources {
                    assert_eq!(
                        out.churn.state.remaining_at(r, k),
                        reference.1[r * p.num_resources + k],
                        "{ctx}: remaining({r},{k}) diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn tiny_capacity_stream_stays_bitwise_across_modes() {
    // a lane smaller than the refill burst forces many short refill
    // rounds per batch; the model's same-thread refill is lossless by
    // contract, so both modes must see identical batches *and*
    // identical queue accounting (pushed grows, dropped stays zero)
    let mut rng = Rng::new(base_seed() ^ 0xD0);
    let p = random_problem(&mut rng, Size { scale: 1.0 });
    let horizon = 24;
    let run = |mode: PipelineMode| {
        let params = StreamParams {
            batch_events: 8,
            capacity: 8,
            burst: 32,
            backpressure: false,
            ..StreamParams::default()
        };
        let mut arr = StreamArrivals::new(p.num_ports(), params, 97);
        let mut pol = Fairness::new();
        pol.reset(&p);
        let mut leader = ShardedLeader::new(&p, 2);
        let out = run_pipeline(&mut leader, &mut pol, &mut arr, horizon, mode);
        (out.result.records.clone(), arr.queue().pushed(), arr.queue().dropped())
    };
    let (lock, lock_pushed, lock_dropped) = run(PipelineMode::Lockstep);
    let (over, over_pushed, over_dropped) = run(PipelineMode::Overlapped);
    assert_eq!(over, lock, "tiny-capacity records diverged across modes");
    assert_eq!(over_pushed, lock_pushed, "pushed counters diverged across modes");
    assert!(lock_pushed >= (horizon as u64) * 8, "batches must flow through the lane");
    assert_eq!((lock_dropped, over_dropped), (0, 0), "lossless refill must never drop");
}
