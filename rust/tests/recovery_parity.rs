//! Recovery parity (§Recover): a run that is **killed at injected
//! slots and resumed from its last durable checkpoint** must reproduce
//! the same run uninterrupted **bit for bit** — every slot record
//! (q, gain, penalty, arrivals), the cumulative reward, the final
//! ledger (remaining capacity per (r, k)) and, for the learning
//! policy, the final decision tensor — across the policy lineup ×
//! worker budgets {1, 2, 4} × checkpoint epochs {1, 5, 17} × random
//! execution-fault streams, composed with PR 6's topology churn.
//!
//! Injected worker panics and stalls are likewise required to be
//! *survived* (the process never aborts; the pool catches, reports and
//! retries them inline) and *float-invisible* (they fire before any
//! write, so the retried task recomputes identical bits).
//!
//! The diagnostic ledger running totals (`total_units`/`total_comp`)
//! are deliberately NOT compared: extra segment cuts re-sum them in
//! flat order versus the compensated incremental accumulation, which
//! perturbs low bits of those two telemetry scalars only — never the
//! usage grid, the decisions, or the rewards (see `sim::checkpoint`).
//!
//! §SStore extends the contract to **storage faults**: checkpoint
//! blobs may be torn (truncated), bit-flipped or lost entirely
//! (rename never lands), again deterministically per (slot, seed).
//! Recovery must *never* thaw a damaged blob — every rejection is
//! counted (`blobs_rejected`) and the chain walk falls back to the
//! newest intact checkpoint (`thaw_fallbacks`), replaying forward to
//! the same bits as the uninterrupted run.
//!
//! The CI matrix re-runs this suite under several exec-fault seeds
//! (`RECOVERY_FAULT_SEED`) × `PALLAS_WORKERS` with `--test-threads=1`.

use ogasched::config::{FaultConfig, RecoveryConfig};
use ogasched::graph::Bipartite;
use ogasched::model::Problem;
use ogasched::oga::utilities::UtilityKind;
use ogasched::schedulers::{
    BinPacking, Drf, Fairness, OgaMirror, OgaSched, Policy, RandomAlloc, Spreading,
};
use ogasched::sim::arrivals::Bernoulli;
use ogasched::sim::checkpoint::{run_resilient, run_resilient_with_store, ResilientOutcome};
use ogasched::sim::faults::{run_churned, ChurnOutcome, ExecFaultPlan, FaultPlan};
use ogasched::sim::ingest::{StreamArrivals, StreamParams};
use ogasched::sim::store::BlobStore;
use ogasched::utils::codec;
use ogasched::utils::prop::{check_seeded, ensure, Size};
use ogasched::utils::rng::Rng;
use ogasched::ExecBudget;

const SHARD_COUNTS: [usize; 3] = [1, 2, 4];
const CHECKPOINT_EPOCHS: [usize; 3] = [1, 5, 17];

/// Exec-fault seed for the property matrix; the CI recovery-parity job
/// sweeps this via the environment so different kill/panic streams hit
/// the same parity contract.
fn fault_base_seed() -> u64 {
    std::env::var("RECOVERY_FAULT_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xFACADE)
}

fn random_problem(rng: &mut Rng, size: Size) -> Problem {
    let l_n = rng.range(1, size.dim(6, 1));
    let r_n = rng.range(2, size.dim(16, 2).max(3));
    let k_n = rng.range(1, size.dim(4, 1));
    let p = rng.uniform(0.2, 0.9);
    let mut edges = Vec::new();
    for l in 0..l_n {
        for r in 0..r_n {
            if rng.bernoulli(p) {
                edges.push((l, r));
            }
        }
    }
    let graph = Bipartite::from_edges(l_n, r_n, &edges);
    Problem::new(
        graph,
        k_n,
        (0..l_n * k_n).map(|_| rng.uniform(0.2, 3.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 4.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 2.0)).collect(),
        (0..r_n * k_n).map(|_| UtilityKind::ALL[rng.below(4)]).collect(),
        (0..k_n).map(|_| rng.uniform(0.1, 0.8)).collect(),
    )
}

fn make_policy(p: &Problem, i: usize, seed: u64) -> (&'static str, Box<dyn Policy + Send>) {
    match i {
        0 => ("oga-reactive", Box::new(OgaSched::new(p, 2.0, 0.999, ExecBudget::auto()))),
        1 => ("oga-reservation", Box::new(OgaSched::reservation(p, 2.0, 0.999, ExecBudget::auto()))),
        2 => ("oga-mirror", Box::new(OgaMirror::new(p, 2.0, 0.999, ExecBudget::auto()))),
        3 => ("drf", Box::new(Drf::new())),
        4 => ("fairness", Box::new(Fairness::new())),
        5 => ("binpacking", Box::new(BinPacking::new())),
        6 => ("spreading", Box::new(Spreading::new())),
        _ => ("random", Box::new(RandomAlloc::new(seed))),
    }
}

const N_POLICIES: usize = 8;

fn churny(seed: u64) -> FaultConfig {
    FaultConfig {
        instance_rate: 0.06,
        recover_rate: 0.25,
        port_rate: 0.04,
        rack_rate: 0.02,
        rack_size: 2,
        seed,
        ..FaultConfig::default()
    }
}

#[allow(clippy::too_many_arguments)]
fn uninterrupted(
    p: &Problem,
    policy: &mut dyn Policy,
    plan: &FaultPlan,
    cfg: &FaultConfig,
    horizon: usize,
    shards: usize,
    arrival_seed: u64,
    rho: f64,
) -> Result<ChurnOutcome, String> {
    policy.reset(p);
    let mut arr = Bernoulli::uniform(p.num_ports(), rho, arrival_seed);
    run_churned(p, policy, &mut arr, horizon, shards, plan, cfg, false)
}

#[allow(clippy::too_many_arguments)]
fn crashed(
    p: &Problem,
    policy: &mut dyn Policy,
    plan: &FaultPlan,
    cfg: &FaultConfig,
    horizon: usize,
    shards: usize,
    arrival_seed: u64,
    rho: f64,
    rebuild: bool,
    recovery: &RecoveryConfig,
    exec: &ExecFaultPlan,
) -> Result<ResilientOutcome, String> {
    policy.reset(p);
    let mut arr = Bernoulli::uniform(p.num_ports(), rho, arrival_seed);
    run_resilient(
        p, policy, &mut arr, horizon, shards, plan, cfg, rebuild, recovery, exec,
    )
}

fn compare(ctx: &str, got: &ChurnOutcome, want: &ChurnOutcome) -> Result<(), String> {
    ensure(got.result.cumulative_reward == want.result.cumulative_reward, || {
        format!(
            "{ctx}: cumulative {} vs {}",
            got.result.cumulative_reward, want.result.cumulative_reward
        )
    })?;
    ensure(got.result.clamped_total == want.result.clamped_total, || {
        format!("{ctx}: clamped totals diverged")
    })?;
    ensure(got.result.records == want.result.records, || {
        let at = got
            .result
            .records
            .iter()
            .zip(&want.result.records)
            .position(|(a, b)| a != b);
        format!("{ctx}: slot records diverged (first at {at:?})")
    })?;
    ensure(
        (got.editions, got.replans, got.events) == (want.editions, want.replans, want.events),
        || {
            format!(
                "{ctx}: churn counters ({}, {}, {}) vs ({}, {}, {})",
                got.editions, got.replans, got.events, want.editions, want.replans, want.events
            )
        },
    )?;
    for r in 0..want.problem.num_instances() {
        for k in 0..want.problem.num_resources {
            ensure(got.state.remaining_at(r, k) == want.state.remaining_at(r, k), || {
                format!(
                    "{ctx}: remaining({r},{k}) {} vs {}",
                    got.state.remaining_at(r, k),
                    want.state.remaining_at(r, k)
                )
            })?;
        }
    }
    ensure(got.problem.num_edges() == want.problem.num_edges(), || {
        format!(
            "{ctx}: final editions differ ({} vs {} edges)",
            got.problem.num_edges(),
            want.problem.num_edges()
        )
    })?;
    Ok(())
}

#[test]
fn crashed_and_resumed_matches_uninterrupted_bitwise() {
    check_seeded("recovery-parity", fault_base_seed(), 3, |rng, size| {
        let p = random_problem(rng, size);
        let horizon = 36;
        let cfg = churny(rng.below(1 << 30) as u64);
        let plan = FaultPlan::for_problem(&p, horizon, &cfg);
        let arrival_seed = rng.below(1 << 30) as u64;
        let policy_seed = rng.below(1 << 30) as u64;
        let exec_seed = rng.below(1 << 30) as u64;
        for i in 0..N_POLICIES {
            let (name, mut pol) = make_policy(&p, i, policy_seed);
            let reference =
                uninterrupted(&p, pol.as_mut(), &plan, &cfg, horizon, 1, arrival_seed, 0.6)
                    .map_err(|e| format!("{name} uninterrupted: {e}"))?;
            ensure(reference.result.records.len() == horizon, || {
                format!("{name}: expected {horizon} records")
            })?;
            for &shards in &SHARD_COUNTS {
                for &epoch in &CHECKPOINT_EPOCHS {
                    let rcfg = RecoveryConfig {
                        checkpoint_epoch: epoch,
                        panic_rate: 0.04,
                        stall_rate: 0.02,
                        kill_rate: 0.08,
                        ckpt_fail_rate: 0.15,
                        stall_ms: 1,
                        seed: exec_seed ^ (epoch as u64) << 8 ^ shards as u64,
                    };
                    let exec = ExecFaultPlan::generate(horizon, shards, &rcfg);
                    let (_, mut pol) = make_policy(&p, i, policy_seed);
                    let out = crashed(
                        &p, pol.as_mut(), &plan, &cfg, horizon, shards, arrival_seed, 0.6,
                        false, &rcfg, &exec,
                    )
                    .map_err(|e| format!("{name} shards={shards} epoch={epoch}: {e}"))?;
                    let ctx = format!("{name} shards={shards} epoch={epoch}");
                    ensure(out.kills == exec.kills.len(), || {
                        format!(
                            "{ctx}: {} of {} kills taken",
                            out.kills,
                            exec.kills.len()
                        )
                    })?;
                    ensure(out.checkpoints_written > 0, || {
                        format!("{ctx}: no checkpoint written")
                    })?;
                    ensure(out.restored_from.len() == out.kills, || {
                        format!("{ctx}: restores != kills")
                    })?;
                    // no storage faults armed: every blob in the chain
                    // is intact, so no rejection/fallback may fire and
                    // rewrites never exceed total writes
                    ensure(out.blobs_rejected == 0 && out.thaw_fallbacks == 0, || {
                        format!(
                            "{ctx}: phantom storage rejection ({} rejected, {} fallbacks)",
                            out.blobs_rejected, out.thaw_fallbacks
                        )
                    })?;
                    ensure(out.checkpoints_written > out.checkpoints_rewritten, || {
                        format!("{ctx}: no fresh checkpoint write in the split")
                    })?;
                    compare(&ctx, &out.churn, &reference)?;
                }
            }
            // composition: the rebuild churn arm under crash-recovery
            // still equals the incremental uninterrupted reference
            let rcfg = RecoveryConfig {
                checkpoint_epoch: 5,
                kill_rate: 0.1,
                seed: exec_seed ^ 0xB00,
                ..RecoveryConfig::default()
            };
            let exec = ExecFaultPlan::generate(horizon, 2, &rcfg);
            let (_, mut pol) = make_policy(&p, i, policy_seed);
            let out = crashed(
                &p, pol.as_mut(), &plan, &cfg, horizon, 2, arrival_seed, 0.6, true, &rcfg,
                &exec,
            )
            .map_err(|e| format!("{name} rebuild resilient: {e}"))?;
            compare(&format!("{name} rebuild resilient"), &out.churn, &reference)?;
        }
        Ok(())
    });
}

#[test]
fn crashed_decision_tensors_match_uninterrupted() {
    // the learning policy's final y — snapshotted, killed, thawed,
    // replayed — is bit-identical to the uninterrupted tensor, for
    // every worker budget and checkpoint cadence
    let mut rng = Rng::new(fault_base_seed() ^ 0x5EED);
    let p = random_problem(&mut rng, Size { scale: 1.0 });
    let horizon = 50;
    let cfg = churny(9);
    let plan = FaultPlan::for_problem(&p, horizon, &cfg);
    let reference = {
        let mut pol = OgaSched::new(&p, 2.0, 0.999, ExecBudget::auto());
        let out = uninterrupted(&p, &mut pol, &plan, &cfg, horizon, 1, 17, 0.5).unwrap();
        (pol.current_decision().to_vec(), out)
    };
    assert_eq!(reference.0.len(), reference.1.problem.decision_len());
    for &shards in &SHARD_COUNTS {
        for &epoch in &CHECKPOINT_EPOCHS {
            let rcfg = RecoveryConfig {
                checkpoint_epoch: epoch,
                kill_rate: 0.1,
                ckpt_fail_rate: 0.1,
                seed: 31 + epoch as u64,
                ..RecoveryConfig::default()
            };
            let exec = ExecFaultPlan::generate(horizon, shards, &rcfg);
            let mut pol = OgaSched::new(&p, 2.0, 0.999, ExecBudget::auto());
            let out =
                crashed(&p, &mut pol, &plan, &cfg, horizon, shards, 17, 0.5, false, &rcfg, &exec)
                    .unwrap();
            assert!(out.kills > 0 || exec.kills.is_empty());
            compare(
                &format!("y-parity shards={shards} epoch={epoch}"),
                &out.churn,
                &reference.1,
            )
            .unwrap();
            assert_eq!(
                pol.current_decision(),
                &reference.0[..],
                "decision tensors diverged at shards={shards} epoch={epoch}"
            );
        }
    }
}

#[test]
fn kill_storm_without_epochs_replays_from_slot_zero() {
    // checkpoint_epoch = 0 means only the implicit slot-0 snapshot
    // exists: every kill replays the whole prefix — slow but legal,
    // and still bitwise
    let mut rng = Rng::new(fault_base_seed() ^ 0xC0);
    let p = random_problem(&mut rng, Size { scale: 1.0 });
    let horizon = 24;
    let cfg = churny(5);
    let plan = FaultPlan::for_problem(&p, horizon, &cfg);
    let recovery = RecoveryConfig::default(); // checkpoint_epoch: 0
    let exec = ExecFaultPlan { kills: vec![4, 9, 21], ..ExecFaultPlan::default() };
    for &shards in &[1usize, 4] {
        let (_, mut pol) = make_policy(&p, 0, 1);
        let reference =
            uninterrupted(&p, pol.as_mut(), &plan, &cfg, horizon, 1, 77, 0.7).unwrap();
        let (_, mut pol) = make_policy(&p, 0, 1);
        let out = crashed(
            &p, pol.as_mut(), &plan, &cfg, horizon, shards, 77, 0.7, false, &recovery, &exec,
        )
        .unwrap();
        assert_eq!(out.kills, 3);
        assert_eq!(out.restored_from, vec![0, 0, 0]);
        // telemetry split (§SStore satellite): with epoch 0 the only
        // boundary is the implicit slot-0 snapshot, written exactly once
        // — replay arriving back at slot 0 finds it as the chain's
        // newest blob and dedups, so no boundary re-write is counted
        assert_eq!(out.checkpoints_written, 1, "shards={shards}: slot-0 write double-counted");
        assert_eq!(out.checkpoints_rewritten, 0, "shards={shards}: phantom replay re-write");
        assert_eq!((out.blobs_rejected, out.thaw_fallbacks), (0, 0));
        compare(&format!("kill-storm shards={shards}"), &out.churn, &reference).unwrap();
    }
}

#[test]
fn kills_mid_batch_resume_the_ingest_stream_bitwise() {
    // §SPerf-9 satellite: with the streaming-ingest arrival model,
    // every checkpoint drains the in-flight lane into the batcher
    // before freezing (shutdown drain hook + `ingest_checkpoint`), so
    // a kill taken mid-batch — the burst (13) never divides the batch
    // shape (8), leaving stranded events at every boundary — thaws the
    // v2 ingest cursor/batch-state section and resumes bitwise, under
    // churn and at every worker budget.
    let mut rng = Rng::new(fault_base_seed() ^ 0x1497);
    let p = random_problem(&mut rng, Size { scale: 1.0 });
    let horizon = 33;
    let cfg = churny(21);
    let plan = FaultPlan::for_problem(&p, horizon, &cfg);
    let params = StreamParams { batch_events: 8, burst: 13, ..StreamParams::default() };
    let reference = {
        let (_, mut pol) = make_policy(&p, 0, 3);
        pol.reset(&p);
        let mut arr = StreamArrivals::new(p.num_ports(), params, 555);
        run_churned(&p, pol.as_mut(), &mut arr, horizon, 1, &plan, &cfg, false).unwrap()
    };
    for &shards in &SHARD_COUNTS {
        let rcfg = RecoveryConfig {
            checkpoint_epoch: 3,
            kill_rate: 0.12,
            ckpt_fail_rate: 0.1,
            seed: 91 + shards as u64,
            ..RecoveryConfig::default()
        };
        let exec = ExecFaultPlan::generate(horizon, shards, &rcfg);
        let (_, mut pol) = make_policy(&p, 0, 3);
        pol.reset(&p);
        let mut arr = StreamArrivals::new(p.num_ports(), params, 555);
        let out = run_resilient(
            &p, pol.as_mut(), &mut arr, horizon, shards, &plan, &cfg, false, &rcfg, &exec,
        )
        .unwrap();
        assert_eq!(out.kills, exec.kills.len(), "ingest shards={shards}: kills not taken");
        assert!(out.checkpoints_written > 0, "ingest shards={shards}: nothing frozen");
        compare(&format!("ingest-resilient shards={shards}"), &out.churn, &reference)
            .unwrap();
        // lossless cursor: every event the stream generated was either
        // batched out through `next` or parked in checkpointable state
        assert_eq!(arr.queue().dropped(), 0, "ingest shards={shards}: stream dropped");
    }
}

#[test]
fn corrupted_chains_fall_back_and_stay_bitwise() {
    // §SStore tentpole matrix: lineup × chain depths {1, 2, 5} under
    // seeded torn writes, bit flips and lost renames.  Recovery must
    // reject every damaged blob it meets (surfaced in
    // `blobs_rejected`), fall back along the chain, and still replay
    // to the uninterrupted bits.  A deterministic floor — one kill
    // whose preceding boundary blob is always torn — guarantees the
    // fallback path fires in every config regardless of the CI seed.
    check_seeded("sstore-parity", fault_base_seed() ^ 0x57, 3, |rng, size| {
        let p = random_problem(rng, size);
        let horizon = 34;
        let epoch = 4u64;
        let cfg = churny(rng.below(1 << 30) as u64);
        let plan = FaultPlan::for_problem(&p, horizon, &cfg);
        let arrival_seed = rng.below(1 << 30) as u64;
        let policy_seed = rng.below(1 << 30) as u64;
        let exec_seed = rng.below(1 << 30) as u64;
        for i in 0..N_POLICIES {
            let (name, mut pol) = make_policy(&p, i, policy_seed);
            let reference =
                uninterrupted(&p, pol.as_mut(), &plan, &cfg, horizon, 1, arrival_seed, 0.6)
                    .map_err(|e| format!("{name} uninterrupted: {e}"))?;
            for &depth in &[1usize, 2, 5] {
                let rcfg = RecoveryConfig {
                    checkpoint_epoch: epoch as usize,
                    kill_rate: 0.08,
                    ckpt_fail_rate: 0.1,
                    chain_depth: depth,
                    torn_write_rate: 0.25,
                    bit_flip_rate: 0.25,
                    lost_rename_rate: 0.15,
                    seed: exec_seed ^ (depth as u64) << 4,
                    ..RecoveryConfig::default()
                };
                let mut exec = ExecFaultPlan::generate(horizon, 2, &rcfg);
                let forced_kill = horizon as u64 - 1;
                if !exec.kills.contains(&forced_kill) {
                    exec.kills.push(forced_kill);
                    exec.kills.sort_unstable();
                }
                let boundary = (forced_kill / epoch) * epoch;
                exec.torn_writes.insert(boundary, 0xA11CE);
                exec.lost_renames.remove(&boundary);
                exec.ckpt_fails.remove(&boundary);
                let (_, mut pol) = make_policy(&p, i, policy_seed);
                let out = crashed(
                    &p, pol.as_mut(), &plan, &cfg, horizon, 2, arrival_seed, 0.6, false,
                    &rcfg, &exec,
                )
                .map_err(|e| format!("{name} depth={depth}: {e}"))?;
                let ctx = format!("{name} depth={depth}");
                ensure(out.kills == exec.kills.len(), || {
                    format!("{ctx}: {} of {} kills taken", out.kills, exec.kills.len())
                })?;
                ensure(out.restored_from.len() == out.kills, || {
                    format!("{ctx}: restores != kills")
                })?;
                // zero silent thaws: the forced torn boundary sits
                // newest in the chain at the forced kill, so at least
                // one rejection + fallback must have been surfaced
                ensure(out.blobs_rejected >= 1 && out.thaw_fallbacks >= 1, || {
                    format!(
                        "{ctx}: damaged blob thawed silently ({} rejected, {} fallbacks)",
                        out.blobs_rejected, out.thaw_fallbacks
                    )
                })?;
                // every fallback implies at least one rejection on its walk
                ensure(out.blobs_rejected >= out.thaw_fallbacks, || {
                    format!("{ctx}: fallbacks exceed rejections")
                })?;
                ensure(out.checkpoints_written >= out.checkpoints_rewritten, || {
                    format!("{ctx}: rewrite split exceeds total writes")
                })?;
                compare(&ctx, &out.churn, &reference)?;
            }
        }
        Ok(())
    });
}

#[test]
fn storm_with_only_the_genesis_intact_replays_from_slot_zero() {
    // §SStore worst case: *every* checkpoint blob except epoch 0's is
    // torn.  Both kills must walk the whole chain, reject everything
    // newer, land on the genesis blob, and replay from slot 0 to the
    // uninterrupted bits.  The write/rewrite split is hand-traced:
    // fresh boundaries {0,5,10} pre-kill-1, rewrites {0,5,10} +
    // fresh {15,20} between kills, rewrites {0,5,10,15,20} + fresh
    // {25} after kill-2 — 14 writes, 8 of them replay re-writes —
    // and is independent of the chain depth (dedup keys on the
    // newest slot only).
    let mut rng = Rng::new(fault_base_seed() ^ 0x570);
    let p = random_problem(&mut rng, Size { scale: 1.0 });
    let horizon = 30;
    let cfg = churny(7);
    let plan = FaultPlan::for_problem(&p, horizon, &cfg);
    let mut exec = ExecFaultPlan { kills: vec![13, 23], ..ExecFaultPlan::default() };
    for s in (5..horizon as u64).step_by(5) {
        exec.torn_writes.insert(s, 0xD00D + s);
    }
    let (_, mut pol) = make_policy(&p, 0, 1);
    let reference = uninterrupted(&p, pol.as_mut(), &plan, &cfg, horizon, 1, 77, 0.7).unwrap();
    for &depth in &[2usize, 5] {
        let rcfg = RecoveryConfig {
            checkpoint_epoch: 5,
            chain_depth: depth,
            ..RecoveryConfig::default()
        };
        let (_, mut pol) = make_policy(&p, 0, 1);
        let out = crashed(
            &p, pol.as_mut(), &plan, &cfg, horizon, 2, 77, 0.7, false, &rcfg, &exec,
        )
        .unwrap();
        assert_eq!(out.kills, 2, "depth={depth}");
        assert_eq!(out.restored_from, vec![0, 0], "depth={depth}: not the genesis blob");
        assert_eq!(out.thaw_fallbacks, 2, "depth={depth}");
        assert!(
            out.blobs_rejected >= 4,
            "depth={depth}: only {} rejections across two full-chain walks",
            out.blobs_rejected
        );
        assert_eq!(out.checkpoints_written, 14, "depth={depth}");
        assert_eq!(out.checkpoints_rewritten, 8, "depth={depth}");
        compare(&format!("genesis-storm depth={depth}"), &out.churn, &reference).unwrap();
    }
}

#[test]
fn gc_keeps_the_chain_bounded_and_never_drops_the_newest_valid_blob() {
    // §SStore satellite: chain GC under a kill storm with storage
    // faults, at depths {1, 2, 5}.  The retained set is deterministic
    // (two identical runs leave identical (epoch, slot) chains), never
    // exceeds depth + the two pins (genesis, newest-valid), always
    // still contains an intact blob, and resuming through GC'd chains
    // stays bitwise.
    let mut rng = Rng::new(fault_base_seed() ^ 0x6C);
    let p = random_problem(&mut rng, Size { scale: 1.0 });
    let horizon = 40;
    let cfg = churny(11);
    let plan = FaultPlan::for_problem(&p, horizon, &cfg);
    let (_, mut pol) = make_policy(&p, 0, 1);
    let reference = uninterrupted(&p, pol.as_mut(), &plan, &cfg, horizon, 1, 177, 0.6).unwrap();
    for &depth in &[1usize, 2, 5] {
        let rcfg = RecoveryConfig {
            checkpoint_epoch: 3,
            kill_rate: 0.1,
            chain_depth: depth,
            torn_write_rate: 0.2,
            bit_flip_rate: 0.1,
            lost_rename_rate: 0.1,
            seed: 1234 + depth as u64,
            ..RecoveryConfig::default()
        };
        let exec = ExecFaultPlan::generate(horizon, 2, &rcfg);
        let chains: Vec<Vec<(u64, u64)>> = (0..2)
            .map(|_| {
                let (_, mut pol) = make_policy(&p, 0, 1);
                pol.reset(&p);
                let mut arr = Bernoulli::uniform(p.num_ports(), 0.6, 177);
                let mut store = BlobStore::memory(depth);
                let out = run_resilient_with_store(
                    &p, pol.as_mut(), &mut arr, horizon, 2, &plan, &cfg, false, &rcfg,
                    &exec, &mut store,
                )
                .unwrap();
                assert!(out.blobs_rejected >= out.thaw_fallbacks, "depth={depth}");
                compare(&format!("gc depth={depth}"), &out.churn, &reference).unwrap();
                assert!(
                    store.len() <= depth + 2,
                    "depth={depth}: chain grew to {} entries",
                    store.len()
                );
                let entries = store.chain();
                assert!(
                    entries.iter().any(|e| {
                        store.load(e).map(|b| codec::verify(&b).is_ok()).unwrap_or(false)
                    }),
                    "depth={depth}: GC left no valid blob in the chain"
                );
                assert_eq!(
                    entries.last().map(|e| (e.epoch, e.slot)),
                    Some((0, 0)),
                    "depth={depth}: genesis blob was GC'd"
                );
                entries.iter().map(|e| (e.epoch, e.slot)).collect()
            })
            .collect();
        assert_eq!(chains[0], chains[1], "depth={depth}: retained set not deterministic");
    }
}

#[test]
fn worker_fault_storm_is_survived_and_float_invisible() {
    // saturating panic/stall rates: the pool must isolate and retry
    // every single one without aborting the process or moving a bit
    let mut rng = Rng::new(fault_base_seed() ^ 0xAB);
    let p = random_problem(&mut rng, Size { scale: 1.0 });
    let horizon = 30;
    let cfg = churny(13);
    let plan = FaultPlan::for_problem(&p, horizon, &cfg);
    for &shards in &SHARD_COUNTS {
        let rcfg = RecoveryConfig {
            checkpoint_epoch: 5,
            panic_rate: 0.5,
            stall_rate: 0.3,
            stall_ms: 1,
            seed: 71,
            ..RecoveryConfig::default()
        };
        let exec = ExecFaultPlan::generate(horizon, shards, &rcfg);
        assert!(!exec.panics.is_empty() && !exec.stalls.is_empty());
        for i in [0usize, 2, 7] {
            let (name, mut pol) = make_policy(&p, i, 3);
            let reference =
                uninterrupted(&p, pol.as_mut(), &plan, &cfg, horizon, shards, 19, 0.8).unwrap();
            let (_, mut pol) = make_policy(&p, i, 3);
            let out = crashed(
                &p, pol.as_mut(), &plan, &cfg, horizon, shards, 19, 0.8, false, &rcfg, &exec,
            )
            .unwrap();
            assert_eq!(out.kills, 0);
            assert!(
                out.worker_faults > 0,
                "{name} shards={shards}: no injected worker fault fired"
            );
            compare(&format!("{name} fault-storm shards={shards}"), &out.churn, &reference)
                .unwrap();
        }
    }
}
