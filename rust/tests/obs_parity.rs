//! Observability parity (§Obs): turning the tracing + metrics layer on
//! must leave every simulated number untouched.  The `obs` module's
//! contract is that it records *integers about* the run (span
//! durations, counters, occupancy edges) and never participates in it —
//! no float passes through a histogram, no RNG draw feeds a span, no
//! code path branches on the level except the recording itself.  These
//! tests prove the contract the same way the shard/churn/recovery
//! suites prove theirs: run the full paper lineup, a churned run and a
//! kill-and-resume resilient run once at `off` and once at `trace`
//! (the most invasive level), and require bitwise-identical slot
//! records, cumulative rewards and recovery telemetry.
//!
//! The obs level is process-global, so every test serializes on `GATE`
//! and restores `Off` before releasing it; CI additionally pins
//! `--test-threads=1` (see `.github/workflows/ci.yml` job `obs-parity`)
//! and sweeps `PALLAS_WORKERS` ∈ {1, 2, 4} so the per-thread rings see
//! one, some and many producer threads.

use std::sync::Mutex;

use ogasched::config::Scenario;
use ogasched::coordinator::RunResult;
use ogasched::obs;
use ogasched::schedulers::OgaSched;
use ogasched::sim;
use ogasched::ExecBudget;

/// Serializes tests in this binary: they all mutate the global obs level.
static GATE: Mutex<()> = Mutex::new(());

/// Run `f` at the given obs level with the registry and rings cleared
/// first, restoring `Off` afterwards.  (A panicking `f` fails the test
/// and poisons `GATE`, which aborts the sibling tests too — fine, since
/// any assertion here means the parity contract is broken.)
fn at_level<T>(level: obs::ObsLevel, f: impl FnOnce() -> T) -> T {
    obs::reset();
    obs::set_level(level);
    let out = f();
    obs::set_level(obs::ObsLevel::Off);
    out
}

fn assert_runs_bitwise_equal(ctx: &str, got: &RunResult, want: &RunResult) {
    assert_eq!(got.policy, want.policy, "{ctx}: policy order diverged");
    assert_eq!(
        got.cumulative_reward, want.cumulative_reward,
        "{ctx} {}: cumulative diverged",
        got.policy
    );
    assert_eq!(
        got.clamped_total, want.clamped_total,
        "{ctx} {}: clamp counts diverged",
        got.policy
    );
    assert_eq!(got.records.len(), want.records.len(), "{ctx} {}", got.policy);
    for (a, b) in got.records.iter().zip(&want.records) {
        assert!(
            a.q == b.q && a.gain == b.gain && a.penalty == b.penalty
                && a.arrivals == b.arrivals,
            "{ctx} {} t={}: ({}, {}, {}) vs ({}, {}, {})",
            got.policy, a.t, a.q, a.gain, a.penalty, b.q, b.gain, b.penalty
        );
    }
}

#[test]
fn lineup_is_bitwise_identical_with_tracing_on() {
    let _gate = GATE.lock().unwrap();
    let mut s = Scenario::default();
    s.horizon = 40;
    let off = at_level(obs::ObsLevel::Off, || sim::run_paper_lineup(&s));
    let traced = at_level(obs::ObsLevel::Trace, || sim::run_paper_lineup(&s));
    assert_eq!(off.len(), traced.len());
    for (got, want) in traced.iter().zip(&off) {
        assert_runs_bitwise_equal("lineup", got, want);
    }
}

#[test]
fn churned_run_is_bitwise_identical_with_tracing_on() {
    let _gate = GATE.lock().unwrap();
    let mut s = Scenario::default();
    s.horizon = 60;
    s.faults.instance_rate = 0.02;
    s.faults.recover_rate = 0.2;
    s.faults.seed = 7;
    let run = |level| {
        at_level(level, || {
            let p = ogasched::traces::synthesize(&s);
            let mut pol = OgaSched::new(&p, s.eta0, s.decay, ExecBudget::auto());
            sim::faults::run_churned_scenario(&s, &mut pol, false).expect("churned")
        })
    };
    let off = run(obs::ObsLevel::Off);
    let traced = run(obs::ObsLevel::Trace);
    assert_runs_bitwise_equal("churn", &traced.result, &off.result);
    assert_eq!(traced.events, off.events, "churn: event counts diverged");
    assert_eq!(traced.editions, off.editions, "churn: editions diverged");
    assert_eq!(traced.replans, off.replans, "churn: replans diverged");
}

#[test]
fn resilient_run_is_bitwise_identical_with_tracing_on() {
    let _gate = GATE.lock().unwrap();
    let mut s = Scenario::default();
    s.horizon = 60;
    s.recovery.checkpoint_epoch = 5;
    s.recovery.kill_rate = 0.04;
    s.recovery.ckpt_fail_rate = 0.1;
    s.recovery.seed = 11;
    let run = |level| {
        at_level(level, || {
            let p = ogasched::traces::synthesize(&s);
            let mut pol = OgaSched::new(&p, s.eta0, s.decay, ExecBudget::auto());
            sim::checkpoint::run_resilient_scenario(&s, &mut pol, false)
                .expect("resilient")
        })
    };
    let off = run(obs::ObsLevel::Off);
    let traced = run(obs::ObsLevel::Trace);
    assert_runs_bitwise_equal("recover", &traced.churn.result, &off.churn.result);
    assert_eq!(traced.kills, off.kills, "recover: kill counts diverged");
    assert_eq!(
        traced.restored_from, off.restored_from,
        "recover: restore points diverged"
    );
    assert_eq!(
        traced.checkpoints_written, off.checkpoints_written,
        "recover: checkpoint counts diverged"
    );
    assert_eq!(
        traced.checkpoints_failed, off.checkpoints_failed,
        "recover: dropped-checkpoint counts diverged"
    );
}

#[test]
fn traced_run_exports_spans_and_metrics() {
    let _gate = GATE.lock().unwrap();
    let mut s = Scenario::default();
    s.horizon = 20;
    at_level(obs::ObsLevel::Trace, || {
        let _ = sim::run_paper_lineup(&s);
        let jsonl = obs::export::render_jsonl();
        let first = jsonl.lines().next().expect("meta line");
        assert!(
            first.contains("\"schema\":\"ogasched-obs\"") && first.contains("\"version\":1"),
            "meta line malformed: {first}"
        );
        assert!(
            jsonl.lines().any(|l| l.contains("\"record\":\"span\"")),
            "no spans captured by a traced lineup"
        );
        assert!(
            jsonl.lines().any(|l| l.contains("\"slot.decide\"")),
            "decide phase missing from the trace"
        );
        let chrome = obs::export::render_chrome_trace();
        assert!(chrome.starts_with('{') && chrome.ends_with('}'));
        assert!(chrome.contains("\"traceEvents\""));
        assert!(chrome.contains("\"ph\":\"X\""), "no duration events in trace");
        let table = obs::export::summary_table().render();
        assert!(table.contains("span.slot.ns"), "summary missing slot span row");
    });
}

#[test]
fn summary_level_records_histograms_without_rings() {
    let _gate = GATE.lock().unwrap();
    let mut s = Scenario::default();
    s.horizon = 10;
    at_level(obs::ObsLevel::Summary, || {
        let _ = sim::run_paper_lineup(&s);
        let hists = obs::registry().histograms();
        let slot = hists
            .iter()
            .find(|(name, _)| name == "span.slot.ns")
            .map(|(_, snap)| snap.clone())
            .expect("slot span histogram");
        assert!(slot.count > 0, "summary level recorded no slot spans");
        assert!(slot.p50() <= slot.p99());
        // rings stay empty below `trace`
        let jsonl = obs::export::render_jsonl();
        assert!(
            !jsonl.lines().any(|l| l.contains("\"record\":\"span\"")),
            "summary level must not append to rings"
        );
    });
}
