//! Integration: the PJRT-compiled OGA step (artifacts/*.hlo.txt, f32)
//! must agree with the native Rust implementation (f64) over whole
//! trajectories.  This is the cross-layer correctness seam of the
//! three-layer architecture — if it holds, the Python ref.py oracle,
//! the Pallas kernels, the fused L2 projection, and the Rust gradient/
//! projection all compute the same algorithm.
//!
//! Tests are skipped (with a loud message) when artifacts are missing;
//! `make artifacts` builds them.

use ogasched::config::Scenario;
use ogasched::ExecBudget;
use ogasched::coordinator::Leader;
use ogasched::oga::{LearningRate, OgaState};
use ogasched::runtime::{default_dir, HloOgaSched, Manifest, OgaStepExecutor};
use ogasched::schedulers::Policy;
use ogasched::sim::arrivals::{ArrivalModel, Bernoulli};
use ogasched::traces::synthesize;

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load(default_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP runtime parity tests: {e}");
            None
        }
    }
}

fn small_scenario() -> Scenario {
    let mut s = Scenario::small();
    // small bucket is L=4 R=16 K=4 — match it exactly
    s.num_ports = 4;
    s.num_instances = 16;
    s.num_resources = 4;
    s.contention = 2.0;
    s
}

#[test]
fn hlo_step_matches_native_over_trajectory() {
    let Some(manifest) = manifest_or_skip() else { return };
    let s = small_scenario();
    let p = synthesize(&s);
    let mut exec = OgaStepExecutor::new(&manifest, &p).expect("load artifact");
    let mut native = OgaState::new(&p, LearningRate::Constant(0.0), ExecBudget::serial());

    let mut arr = Bernoulli::uniform(p.num_ports(), 0.7, 42);
    let mut x = vec![0.0; p.num_ports()];
    let mut y_hlo = vec![0.0; p.decision_len()];
    let eta = 0.5;
    native.lr = LearningRate::Constant(eta);

    for t in 0..40 {
        arr.next(&mut x);
        exec.step(&x, eta).expect("pjrt step");
        native.step(&p, &x);
        exec.current_decision(&mut y_hlo);
        // f32 artifact vs f64 native: tolerance covers accumulation drift
        let mut max_err = 0.0f64;
        for i in 0..y_hlo.len() {
            max_err = max_err.max((y_hlo[i] - native.y[i]).abs());
        }
        assert!(
            max_err < 5e-3,
            "decision divergence {max_err} at slot {t} (f32 vs f64 paths)"
        );
    }
}

#[test]
fn hlo_reward_triple_matches_native_reward() {
    let Some(manifest) = manifest_or_skip() else { return };
    let s = small_scenario();
    let p = synthesize(&s);
    let mut exec = OgaStepExecutor::new(&manifest, &p).expect("load artifact");
    let mut y = vec![0.0; p.decision_len()];
    let x = vec![1.0; p.num_ports()];
    for _ in 0..10 {
        // reward triple reported by the artifact is for the PRE-step y
        exec.current_decision(&mut y);
        let want = ogasched::reward::slot_reward(&p, &x, &y);
        let got = exec.step(&x, 0.4).expect("pjrt step");
        let tol = 1e-3 * (1.0 + want.q.abs());
        assert!((got.q - want.q).abs() < tol, "q {} vs {}", got.q, want.q);
        assert!((got.gain - want.gain).abs() < tol);
        assert!((got.penalty - want.penalty).abs() < tol);
    }
}

#[test]
fn hlo_policy_runs_under_leader_with_padding() {
    let Some(manifest) = manifest_or_skip() else { return };
    // deliberately smaller than the bucket: exercises zero-padding
    let mut s = Scenario::small();
    s.num_ports = 3;
    s.num_instances = 11;
    s.num_resources = 4;
    s.horizon = 60;
    let p = synthesize(&s);
    let mut pol = HloOgaSched::new(&manifest, &p, 5.0, 0.999).expect("policy");
    assert_eq!(pol.bucket_name(), "small");
    let mut leader = Leader::new(&p);
    let mut arr = Bernoulli::uniform(p.num_ports(), 0.7, 7);
    let run = leader.run(&mut pol, &mut arr, s.horizon);
    assert_eq!(run.records.len(), s.horizon);
    assert_eq!(run.clamped_total, 0, "HLO decisions must be feasible");
    assert!(run.cumulative_reward > 0.0);
}

#[test]
fn hlo_policy_reset_restarts_cleanly() {
    let Some(manifest) = manifest_or_skip() else { return };
    let s = small_scenario();
    let p = synthesize(&s);
    let mut pol = HloOgaSched::new(&manifest, &p, 5.0, 0.999).expect("policy");
    let x = vec![1.0; p.num_ports()];
    let mut y = vec![0.0; p.decision_len()];
    pol.decide(&p, &x, &mut y);
    let first = y.clone();
    pol.decide(&p, &x, &mut y);
    assert!(y.iter().any(|&v| v > 0.0));
    pol.reset(&p);
    pol.decide(&p, &x, &mut y);
    assert_eq!(y, first, "after reset, the trajectory restarts identically");
}
