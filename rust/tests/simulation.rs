//! Integration tests over the whole L3 stack: determinism, lineup
//! invariants, config-file loading, figure harness smoke, and the
//! monotonicity trends the paper's evaluation leans on.

use ogasched::config::{GraphSpec, Scenario};
use ogasched::ExecBudget;
use ogasched::coordinator::Leader;
use ogasched::metrics;
use ogasched::schedulers::{Fairness, OgaSched, Policy};
use ogasched::sim;
use ogasched::sim::arrivals::{ArrivalModel, Bernoulli, Bursty};
use ogasched::traces::{problem_from_csv, synthesize};
use ogasched::traces::loader::{JOBS_SAMPLE, MACHINES_SAMPLE};

#[test]
fn whole_lineup_deterministic_across_processes_shape() {
    let mut s = Scenario::small();
    s.horizon = 120;
    let a: Vec<f64> =
        sim::run_paper_lineup(&s).iter().map(|r| r.cumulative_reward).collect();
    let b: Vec<f64> =
        sim::run_paper_lineup(&s).iter().map(|r| r.cumulative_reward).collect();
    assert_eq!(a, b, "same scenario seed must reproduce bit-identically");
}

#[test]
fn rewards_scale_with_cluster_size() {
    // Fig. 3(a) trend: more instances -> more cumulative reward.
    let run_with = |instances: usize| {
        let mut s = Scenario::small();
        s.num_instances = instances;
        s.horizon = 150;
        let results = sim::run_paper_lineup(&s);
        results[0].cumulative_reward
    };
    let small = run_with(8);
    let big = run_with(64);
    assert!(big > small, "more capacity must raise OGASCHED's reward");
}

#[test]
fn arrival_probability_raises_utilization() {
    // Tab. 3 trend: higher rho -> more arrivals -> more reward (until
    // contention bites; 0.3 -> 0.7 is on the rising side).
    let run_with = |rho: f64| {
        let mut s = Scenario::small();
        s.arrival_prob = rho;
        s.horizon = 200;
        sim::run_paper_lineup(&s)[0].cumulative_reward
    };
    assert!(run_with(0.7) > run_with(0.3));
}

#[test]
fn utility_family_ordering_matches_fig7() {
    use ogasched::oga::utilities::{UtilityKind, UtilityMix};
    // linear >> log/poly >> reciprocal in cumulative reward (Fig. 7)
    let run_mix = |mix: UtilityMix| {
        let mut s = Scenario::small();
        s.utility_mix = mix;
        s.horizon = 200;
        sim::run_paper_lineup(&s)[0].cumulative_reward
    };
    let linear = run_mix(UtilityMix::All(UtilityKind::Linear));
    let log = run_mix(UtilityMix::All(UtilityKind::Log));
    let reciprocal = run_mix(UtilityMix::All(UtilityKind::Reciprocal));
    assert!(linear > log, "linear must beat log (diminishing marginal effect)");
    assert!(log > reciprocal, "log must beat reciprocal (stronger saturation)");
}

#[test]
fn graph_spec_variants_run() {
    for graph in [GraphSpec::Full, GraphSpec::RightRegular(2), GraphSpec::Density(2.0)] {
        let mut s = Scenario::small();
        s.graph = graph;
        s.horizon = 60;
        let results = sim::run_paper_lineup(&s);
        assert_eq!(results.len(), 5);
        for r in &results {
            assert_eq!(r.clamped_total, 0, "{} infeasible under {:?}", r.policy, graph);
        }
    }
}

#[test]
fn csv_trace_cluster_runs_end_to_end() {
    let mut s = Scenario::small();
    s.contention = 1.0;
    s.horizon = 100;
    let p = problem_from_csv(&s, MACHINES_SAMPLE, JOBS_SAMPLE).expect("sample parses");
    let mut leader = Leader::new(&p);
    let mut pol = OgaSched::new(&p, s.eta0, s.decay, ExecBudget::auto());
    let mut arr = Bernoulli::uniform(p.num_ports(), s.arrival_prob, 3);
    let run = leader.run(&mut pol, &mut arr, s.horizon);
    assert!(run.cumulative_reward > 0.0);
    assert_eq!(run.clamped_total, 0);
}

#[test]
fn bursty_arrivals_keep_policies_feasible() {
    let s = Scenario::small();
    let p = synthesize(&s);
    let mut pol = Fairness::new();
    let mut arr = Bursty::new(p.num_ports(), 0.9, 0.1, 0.1, 5);
    let mut leader = Leader::new(&p);
    let run = leader.run(&mut pol, &mut arr, 300);
    assert_eq!(run.clamped_total, 0);
}

#[test]
fn scenario_from_config_file_matches_cli_expectations() {
    let text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("../examples/configs/default.toml"),
    )
    .expect("config shipped with the repo");
    let s = Scenario::from_toml(&text).expect("parses");
    assert_eq!(s.num_ports, 10);
    assert_eq!(s.num_instances, 128);
    assert_eq!(s.horizon, 2000);
    assert_eq!(s.name, "paper-default");
}

#[test]
fn figure_harnesses_smoke_at_tiny_horizon() {
    // fig5/regret are excluded here (large/slow); covered by benches.
    for id in ["fig2", "fig4", "fig6"] {
        let out = ogasched::figures::run_by_id(id, 30).expect(id);
        assert!(!out.rendered.is_empty(), "{id} rendered nothing");
    }
}

#[test]
fn improvement_metric_consistency() {
    let mut s = Scenario::small();
    s.horizon = 150;
    let results = sim::run_paper_lineup(&s);
    let oga = &results[0];
    for r in &results[1..] {
        let pct = metrics::improvement_pct(oga, r);
        let direct = (oga.avg_reward() / r.avg_reward() - 1.0) * 100.0;
        assert!((pct - direct).abs() < 1e-9);
    }
}

#[test]
fn arrival_models_respect_reset_contract() {
    let mut models: Vec<Box<dyn ArrivalModel>> = vec![
        Box::new(Bernoulli::uniform(6, 0.5, 9)),
        Box::new(Bursty::new(6, 0.8, 0.1, 0.2, 9)),
    ];
    for m in models.iter_mut() {
        let mut a = vec![0.0; 6];
        let mut b = vec![0.0; 6];
        m.next(&mut a);
        m.reset(9);
        m.next(&mut b);
        // Bernoulli reproduces exactly; bursty resets state machines
        if m.name() == "bernoulli" {
            assert_eq!(a, b);
        }
    }
}
