//! Churn parity (§Churn): a run whose topology editions are produced by
//! incremental apply/undo (`Problem::remove_instance_edges` /
//! `restore_edges` + `ShardPlan::refresh` under the re-plan epoch rule)
//! must reproduce the same run with every edition rebuilt from scratch
//! (`Bipartite::from_edges` + `Problem::new` + `ShardPlan::build`)
//! **bit for bit**: every slot record (q, gain, penalty, arrivals), the
//! cumulative reward, the final ledger (remaining capacity per (r, k))
//! and, for the learning policy, the final decision tensor — across the
//! policy lineup × worker budgets {1, 2, 4} × random fault plans.  And
//! no decision ever allocates onto a failed instance: its channels are
//! gone from the CSR, so the coordinate cannot be represented.
//!
//! The CI matrix re-runs this suite under several fault seeds
//! (`CHURN_FAULT_SEED`) × `PALLAS_WORKERS` with `--test-threads=1`.

use ogasched::config::FaultConfig;
use ogasched::coordinator::ReleaseMode;
use ogasched::graph::Bipartite;
use ogasched::model::Problem;
use ogasched::oga::utilities::UtilityKind;
use ogasched::schedulers::{
    BinPacking, Drf, Fairness, OgaMirror, OgaSched, Policy, RandomAlloc, Spreading,
};
use ogasched::sim::arrivals::Bernoulli;
use ogasched::sim::faults::{run_churned, ChurnOutcome, FaultEvent, FaultPlan};
use ogasched::utils::prop::{check_seeded, ensure, Size};
use ogasched::utils::rng::Rng;
use ogasched::ExecBudget;

const SHARD_COUNTS: [usize; 3] = [1, 2, 4];

/// Fault seed for the property matrix; the CI churn-parity job sweeps
/// this via the environment so different event streams hit the same
/// parity contract.
fn fault_base_seed() -> u64 {
    std::env::var("CHURN_FAULT_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE)
}

fn random_problem(rng: &mut Rng, size: Size) -> Problem {
    let l_n = rng.range(1, size.dim(6, 1));
    let r_n = rng.range(2, size.dim(16, 2).max(3));
    let k_n = rng.range(1, size.dim(4, 1));
    let p = rng.uniform(0.2, 0.9);
    let mut edges = Vec::new();
    for l in 0..l_n {
        for r in 0..r_n {
            if rng.bernoulli(p) {
                edges.push((l, r));
            }
        }
    }
    let graph = Bipartite::from_edges(l_n, r_n, &edges);
    Problem::new(
        graph,
        k_n,
        (0..l_n * k_n).map(|_| rng.uniform(0.2, 3.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 4.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 2.0)).collect(),
        (0..r_n * k_n).map(|_| UtilityKind::ALL[rng.below(4)]).collect(),
        (0..k_n).map(|_| rng.uniform(0.1, 0.8)).collect(),
    )
}

/// Fixed-capacity problem for the scripted degenerate topologies.
fn tiny_problem(l_n: usize, r_n: usize, k_n: usize, edges: &[(usize, usize)]) -> Problem {
    Problem::new(
        Bipartite::from_edges(l_n, r_n, edges),
        k_n,
        vec![1.0; l_n * k_n],
        vec![4.0; r_n * k_n],
        vec![1.0; r_n * k_n],
        vec![UtilityKind::ALL[0]; r_n * k_n],
        vec![0.3; k_n],
    )
}

fn make_policy(p: &Problem, i: usize, seed: u64) -> (&'static str, Box<dyn Policy + Send>) {
    match i {
        0 => ("oga-reactive", Box::new(OgaSched::new(p, 2.0, 0.999, ExecBudget::auto()))),
        1 => ("oga-reservation", Box::new(OgaSched::reservation(p, 2.0, 0.999, ExecBudget::auto()))),
        2 => ("oga-mirror", Box::new(OgaMirror::new(p, 2.0, 0.999, ExecBudget::auto()))),
        3 => ("drf", Box::new(Drf::new())),
        4 => ("fairness", Box::new(Fairness::new())),
        5 => ("binpacking", Box::new(BinPacking::new())),
        6 => ("spreading", Box::new(Spreading::new())),
        _ => ("random", Box::new(RandomAlloc::new(seed))),
    }
}

const N_POLICIES: usize = 8;

fn arm(
    p: &Problem,
    policy: &mut dyn Policy,
    plan: &FaultPlan,
    cfg: &FaultConfig,
    horizon: usize,
    shards: usize,
    arrival_seed: u64,
    rho: f64,
    rebuild: bool,
) -> Result<ChurnOutcome, String> {
    policy.reset(p);
    let mut arr = Bernoulli::uniform(p.num_ports(), rho, arrival_seed);
    run_churned(p, policy, &mut arr, horizon, shards, plan, cfg, rebuild)
}

/// Final failed/departed masks implied by a plan (for the masking
/// assertions — replayed independently of the driver).
fn final_masks(plan: &FaultPlan, l_n: usize, r_n: usize) -> (Vec<bool>, Vec<bool>) {
    let mut failed = vec![false; r_n];
    let mut departed = vec![false; l_n];
    for &(_, ev) in plan.events() {
        match ev {
            FaultEvent::InstanceFail(r) => failed[r] = true,
            FaultEvent::InstanceRecover(r) => failed[r] = false,
            FaultEvent::PortDepart(l) => departed[l] = true,
            FaultEvent::PortArrive(l) => departed[l] = false,
        }
    }
    (failed, departed)
}

fn compare_outcomes(ctx: &str, got: &ChurnOutcome, want: &ChurnOutcome) -> Result<(), String> {
    ensure(got.result.cumulative_reward == want.result.cumulative_reward, || {
        format!(
            "{ctx}: cumulative {} vs {}",
            got.result.cumulative_reward, want.result.cumulative_reward
        )
    })?;
    ensure(got.result.clamped_total == want.result.clamped_total, || {
        format!("{ctx}: clamped totals diverged")
    })?;
    ensure(got.result.records.len() == want.result.records.len(), || {
        format!("{ctx}: record counts diverged")
    })?;
    for (a, b) in got.result.records.iter().zip(&want.result.records) {
        ensure(
            a.t == b.t && a.q == b.q && a.gain == b.gain && a.penalty == b.penalty
                && a.arrivals == b.arrivals,
            || {
                format!(
                    "{ctx} t={}: ({}, {}, {}) vs ({}, {}, {})",
                    a.t, a.q, a.gain, a.penalty, b.q, b.gain, b.penalty
                )
            },
        )?;
    }
    for r in 0..want.problem.num_instances() {
        for k in 0..want.problem.num_resources {
            ensure(got.state.remaining_at(r, k) == want.state.remaining_at(r, k), || {
                format!(
                    "{ctx}: remaining({r},{k}) {} vs {}",
                    got.state.remaining_at(r, k),
                    want.state.remaining_at(r, k)
                )
            })?;
        }
    }
    ensure(got.problem.num_edges() == want.problem.num_edges(), || {
        format!(
            "{ctx}: final editions differ ({} vs {} edges)",
            got.problem.num_edges(),
            want.problem.num_edges()
        )
    })?;
    Ok(())
}

#[test]
fn churned_incremental_matches_rebuild_bitwise() {
    check_seeded("churn-parity", fault_base_seed(), 5, |rng, size| {
        let p = random_problem(rng, size);
        let horizon = 36;
        let cfg = FaultConfig {
            instance_rate: 0.08,
            recover_rate: 0.25,
            port_rate: 0.05,
            rack_rate: 0.02,
            rack_size: 2,
            release: if rng.bernoulli(0.5) { ReleaseMode::Release } else { ReleaseMode::Drain },
            replan_threshold: if rng.bernoulli(0.5) { 1.0 } else { 1.5 },
            seed: rng.below(1 << 30) as u64,
        };
        let plan = FaultPlan::for_problem(&p, horizon, &cfg);
        let (failed, departed) = final_masks(&plan, p.num_ports(), p.num_instances());
        let arrival_seed = rng.below(1 << 30) as u64;
        let policy_seed = rng.below(1 << 30) as u64;
        for i in 0..N_POLICIES {
            let (name, mut pol) = make_policy(&p, i, policy_seed);
            let reference =
                arm(&p, pol.as_mut(), &plan, &cfg, horizon, 1, arrival_seed, 0.6, false)
                    .map_err(|e| format!("{name} serial incremental: {e}"))?;
            ensure(reference.result.records.len() == horizon, || {
                format!("{name}: expected {horizon} records")
            })?;
            // graceful degradation: dead vertices keep no channels and
            // failed capacity is masked out of the ledger
            for (r, &f) in failed.iter().enumerate() {
                if f {
                    ensure(reference.problem.graph.instance_degree(r) == 0, || {
                        format!("{name}: failed instance {r} kept channels")
                    })?;
                    for k in 0..p.num_resources {
                        ensure(reference.state.remaining_at(r, k) == 0.0, || {
                            format!("{name}: failed instance {r} not masked at k={k}")
                        })?;
                    }
                }
            }
            for (l, &d) in departed.iter().enumerate() {
                if d {
                    ensure(reference.problem.graph.port_edges(l).len() == 0, || {
                        format!("{name}: departed port {l} kept channels")
                    })?;
                }
            }
            for &shards in &SHARD_COUNTS {
                for rebuild in [false, true] {
                    if shards == 1 && !rebuild {
                        continue; // that IS the reference
                    }
                    let (_, mut pol) = make_policy(&p, i, policy_seed);
                    let out = arm(
                        &p, pol.as_mut(), &plan, &cfg, horizon, shards, arrival_seed, 0.6,
                        rebuild,
                    )
                    .map_err(|e| format!("{name} shards={shards} rebuild={rebuild}: {e}"))?;
                    let ctx = format!("{name} shards={shards} rebuild={rebuild}");
                    compare_outcomes(&ctx, &out, &reference)?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn churned_decision_tensors_match_across_arms() {
    // the learning policy's final y after churn is identical whichever
    // arm produced the editions and however the work was sharded
    let mut rng = Rng::new(fault_base_seed() ^ 0x5EED);
    let p = random_problem(&mut rng, Size { scale: 1.0 });
    let horizon = 50;
    let cfg = FaultConfig {
        instance_rate: 0.08,
        recover_rate: 0.3,
        port_rate: 0.04,
        seed: 9,
        ..FaultConfig::default()
    };
    let plan = FaultPlan::for_problem(&p, horizon, &cfg);
    let run_oga = |shards: usize, rebuild: bool| {
        let mut pol = OgaSched::new(&p, 2.0, 0.999, ExecBudget::auto());
        let out = arm(&p, &mut pol, &plan, &cfg, horizon, shards, 17, 0.5, rebuild).unwrap();
        (pol.current_decision().to_vec(), out)
    };
    let (reference_y, reference) = run_oga(1, false);
    assert_eq!(reference_y.len(), reference.problem.decision_len());
    for &shards in &SHARD_COUNTS {
        for rebuild in [false, true] {
            if shards == 1 && !rebuild {
                continue;
            }
            let (y, _) = run_oga(shards, rebuild);
            assert_eq!(
                y,
                reference_y,
                "decision tensors diverged at shards={shards} rebuild={rebuild}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Degenerate topologies: the scripted choreography below exercises the
// corners the random matrix is unlikely to hit.

#[test]
fn zero_degree_port_survives_churn() {
    // port 1 has no channels from day one; churning it (and an
    // instance) must be a harmless no-op that still holds parity
    let p = tiny_problem(3, 3, 2, &[(0, 0), (0, 1), (2, 1), (2, 2)]);
    let plan = FaultPlan::from_events(vec![
        (3, FaultEvent::InstanceFail(1)),
        (4, FaultEvent::PortDepart(1)),
        (7, FaultEvent::InstanceRecover(1)),
        (8, FaultEvent::PortArrive(1)),
    ]);
    let cfg = FaultConfig::default();
    for &shards in &SHARD_COUNTS {
        let inc = arm(&p, &mut Fairness::new(), &plan, &cfg, 12, shards, 3, 0.8, false).unwrap();
        let reb = arm(&p, &mut Fairness::new(), &plan, &cfg, 12, shards, 3, 0.8, true).unwrap();
        compare_outcomes(&format!("zero-degree-port shards={shards}"), &reb, &inc).unwrap();
        assert_eq!(inc.result.records.len(), 12);
        assert_eq!(inc.events, 4);
        assert_eq!(inc.problem.graph.port_edges(1).len(), 0);
        assert!(inc.problem.graph.instance_degree(1) > 0, "instance 1 should be restored");
    }
}

#[test]
fn empty_arrivals_against_fully_failed_shard() {
    // rho = 0 (no work ever arrives) while 3 of 4 instances fail — any
    // 2-shard plan then has at least one fully-failed shard; the run
    // must stay well-defined and hold parity
    let p = tiny_problem(
        3,
        4,
        2,
        &[(0, 0), (0, 1), (1, 1), (1, 2), (2, 2), (2, 3), (0, 3), (1, 0)],
    );
    let plan = FaultPlan::from_events(vec![
        (2, FaultEvent::InstanceFail(0)),
        (2, FaultEvent::InstanceFail(1)),
        (2, FaultEvent::InstanceFail(2)),
    ]);
    let cfg = FaultConfig { release: ReleaseMode::Release, ..FaultConfig::default() };
    for i in [0, 4] {
        for &shards in &[1usize, 2] {
            let (name, mut pol) = make_policy(&p, i, 5);
            let inc = arm(&p, pol.as_mut(), &plan, &cfg, 10, shards, 5, 0.0, false).unwrap();
            let (_, mut pol) = make_policy(&p, i, 5);
            let reb = arm(&p, pol.as_mut(), &plan, &cfg, 10, shards, 5, 0.0, true).unwrap();
            compare_outcomes(&format!("{name} dead-shard shards={shards}"), &reb, &inc)
                .unwrap();
            assert_eq!(inc.result.records.len(), 10);
            for rec in &inc.result.records {
                assert_eq!(rec.arrivals, 0.0, "{name}: rho=0 produced an arrival");
            }
            // only the survivor keeps channels
            for e in 0..inc.problem.num_edges() {
                assert_eq!(inc.problem.graph.edge_instance[e], 3);
            }
        }
    }
}

#[test]
fn single_surviving_instance_serves_alone() {
    let p = tiny_problem(2, 3, 2, &[(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2)]);
    let plan = FaultPlan::from_events(vec![
        (2, FaultEvent::InstanceFail(0)),
        (4, FaultEvent::InstanceFail(1)),
    ]);
    let cfg = FaultConfig::default();
    for &shards in &SHARD_COUNTS {
        let run_arm = |rebuild: bool| {
            let mut pol = OgaSched::new(&p, 2.0, 0.999, ExecBudget::auto());
            let out = arm(&p, &mut pol, &plan, &cfg, 15, shards, 7, 0.9, rebuild).unwrap();
            (pol.current_decision().to_vec(), out)
        };
        let (y_inc, inc) = run_arm(false);
        let (y_reb, reb) = run_arm(true);
        compare_outcomes(&format!("single-survivor shards={shards}"), &reb, &inc).unwrap();
        assert_eq!(y_inc, y_reb, "shards={shards}: decision tensors diverged");
        // every remaining decision coordinate lives on the survivor —
        // allocating onto a failed instance is unrepresentable
        for e in 0..inc.problem.num_edges() {
            assert_eq!(inc.problem.graph.edge_instance[e], 2);
        }
        assert_eq!(y_inc.len(), inc.problem.decision_len());
        for k in 0..p.num_resources {
            assert_eq!(inc.state.remaining_at(0, k), 0.0);
            assert_eq!(inc.state.remaining_at(1, k), 0.0);
        }
    }
}

#[test]
fn recovery_into_previously_empty_kind_run() {
    // instance 0 is the sole member of its utility kind: failing it
    // empties that kind run entirely; recovery must rebuild the run and
    // hold parity through both transitions
    let l_n = 2;
    let r_n = 3;
    let k_n = 2;
    let mut kind = vec![UtilityKind::ALL[0]; r_n * k_n];
    for k in 0..k_n {
        kind[k] = UtilityKind::ALL[1]; // instance 0's row
    }
    let p = Problem::new(
        Bipartite::from_edges(l_n, r_n, &[(0, 0), (0, 1), (1, 0), (1, 2)]),
        k_n,
        vec![1.0; l_n * k_n],
        vec![4.0; r_n * k_n],
        vec![1.0; r_n * k_n],
        kind,
        vec![0.3; k_n],
    );
    let plan = FaultPlan::from_events(vec![
        (2, FaultEvent::InstanceFail(0)),
        (6, FaultEvent::InstanceRecover(0)),
    ]);
    let cfg = FaultConfig::default();
    for &shards in &SHARD_COUNTS {
        let run_arm = |rebuild: bool| {
            let mut pol = OgaSched::new(&p, 2.0, 0.999, ExecBudget::auto());
            let out = arm(&p, &mut pol, &plan, &cfg, 14, shards, 21, 0.8, rebuild).unwrap();
            (pol.current_decision().to_vec(), out)
        };
        let (y_inc, inc) = run_arm(false);
        let (y_reb, reb) = run_arm(true);
        compare_outcomes(&format!("kind-run-recovery shards={shards}"), &reb, &inc).unwrap();
        assert_eq!(y_inc, y_reb, "shards={shards}: decision tensors diverged");
        assert_eq!(inc.events, 2);
        assert_eq!(inc.editions, 2);
        // the kind run repopulated: instance 0 has its channels back
        assert_eq!(inc.problem.graph.instance_degree(0), 2);
    }
}
