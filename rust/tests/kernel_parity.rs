//! Kernel-layer parity (§Perf-5): whichever path the build compiled —
//! the default scalar lane-tree loops or the `--features simd`
//! `std::simd` twins — the leaf kernels must produce **bit-identical**
//! floats to the fixed-width lane-tree accumulation order spelled out
//! here in plain scalar Rust.  Running this suite on stable pins the
//! scalar path to the contract; running it under the advisory nightly
//! `--features simd` CI job pins SIMD == scalar-lane-tree bitwise.
//!
//! Slice lengths cover 0..=2·LANES+1 (resp. 2·LANES_F32+1), so empty
//! slices, exactly-one-block slices and every remainder-lane count are
//! all exercised, across all four Eq. 51 utility families.

use ogasched::oga::kernels::{
    self, grad_f32, value_f32, LANES, LANES_F32,
};
use ogasched::oga::utilities::UtilityKind;
use ogasched::utils::rng::Rng;

/// The contract: LANES independent accumulators over full blocks,
/// combined in a fixed binary tree, sequential remainder added last.
fn lane_tree_f64(kind: UtilityKind, y: &[f64], alpha: &[f64]) -> f64 {
    let n = y.len();
    let blocks = n - n % LANES;
    let mut acc = [0.0f64; LANES];
    let mut i = 0;
    while i < blocks {
        for j in 0..LANES {
            acc[j] += kind.value(y[i + j], alpha[i + j]);
        }
        i += LANES;
    }
    let mut tail = 0.0;
    for j in blocks..n {
        tail += kind.value(y[j], alpha[j]);
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + tail
}

/// The f32 contract (8-lane tree), evaluated through the artifact-path
/// f32 calculus.
fn lane_tree_f32(kind: UtilityKind, y: &[f32], alpha: &[f32]) -> f32 {
    let n = y.len();
    let blocks = n - n % LANES_F32;
    let mut acc = [0.0f32; LANES_F32];
    let mut i = 0;
    while i < blocks {
        for j in 0..LANES_F32 {
            acc[j] += value_f32(kind, y[i + j], alpha[i + j]);
        }
        i += LANES_F32;
    }
    let mut tail = 0.0f32;
    for j in blocks..n {
        tail += value_f32(kind, y[j], alpha[j]);
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

#[test]
fn value_sum_is_bitwise_lane_tree_at_every_remainder() {
    let mut rng = Rng::new(4242);
    for kind in UtilityKind::ALL {
        for n in 0..=2 * LANES + 1 {
            for round in 0..8 {
                let y: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 5.0)).collect();
                let alpha: Vec<f64> = (0..n).map(|_| rng.uniform(0.4, 2.5)).collect();
                let got = kind.value_sum(&y, &alpha);
                let want = lane_tree_f64(kind, &y, &alpha);
                assert!(
                    got == want,
                    "{} n={n} round={round}: {got:?} vs lane tree {want:?}",
                    kind.name()
                );
                // and the module-level entry agrees with the method
                assert!(kernels::value_sum(kind, &y, &alpha) == want);
            }
        }
    }
}

#[test]
fn value_sum_stays_within_ulps_of_sequential_reference() {
    // the lane tree reassociates the sum; the drift from the kept
    // sequential reference must stay at rounding noise on long slices
    let mut rng = Rng::new(7);
    for kind in UtilityKind::ALL {
        for n in [63, 64, 257, 1024] {
            let y: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 5.0)).collect();
            let alpha: Vec<f64> = (0..n).map(|_| rng.uniform(0.4, 2.5)).collect();
            let a = kind.value_sum(&y, &alpha);
            let b = kernels::value_sum_ref(kind, &y, &alpha);
            assert!(
                (a - b).abs() <= 1e-12 * (1.0 + b.abs()),
                "{} n={n}: lane {a} vs sequential {b}",
                kind.name()
            );
        }
    }
}

#[test]
fn grad_into_matches_scalar_calculus_bitwise() {
    let mut rng = Rng::new(99);
    for kind in UtilityKind::ALL {
        for n in 0..=2 * LANES + 1 {
            // negatives exercise the y >= 0 clamp inside f'
            let y: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 5.0)).collect();
            let alpha: Vec<f64> = (0..n).map(|_| rng.uniform(0.4, 2.5)).collect();
            let scale = rng.uniform(0.1, 3.0);
            let mut out = vec![f64::NAN; n];
            kind.grad_into(&y, &alpha, scale, &mut out);
            for i in 0..n {
                let want = scale * kind.grad(y[i], alpha[i]);
                assert!(
                    out[i] == want,
                    "{} n={n} i={i}: {} vs scalar {want}",
                    kind.name(),
                    out[i]
                );
            }
        }
    }
}

#[test]
fn ascend_slice_matches_scalar_calculus_bitwise() {
    let mut rng = Rng::new(123);
    for kind in UtilityKind::ALL {
        for n in 0..=2 * LANES + 1 {
            let y: Vec<f64> = (0..n).map(|_| rng.uniform(-0.2, 5.0)).collect();
            let alpha: Vec<f64> = (0..n).map(|_| rng.uniform(0.4, 2.5)).collect();
            let scale = rng.uniform(0.1, 3.0);
            let mut got = y.clone();
            kind.ascend_slice(&mut got, &alpha, scale);
            for i in 0..n {
                let want = y[i] + scale * kind.grad(y[i], alpha[i]);
                assert!(
                    got[i] == want,
                    "{} n={n} i={i}: {} vs scalar {want}",
                    kind.name(),
                    got[i]
                );
            }
        }
    }
}

#[test]
fn accumulate_is_bitwise_elementwise_add() {
    let mut rng = Rng::new(55);
    for n in 0..=2 * LANES + 1 {
        let base: Vec<f64> = (0..n).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let add: Vec<f64> = (0..n).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let mut acc = base.clone();
        kernels::accumulate(&mut acc, &add);
        for i in 0..n {
            assert!(acc[i] == base[i] + add[i], "n={n} i={i}");
        }
    }
}

#[test]
fn f32_kernels_are_bitwise_lane_tree_at_every_remainder() {
    let mut rng = Rng::new(2024);
    for kind in UtilityKind::ALL {
        for n in 0..=2 * LANES_F32 + 1 {
            let y: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 5.0) as f32).collect();
            let alpha: Vec<f32> = (0..n).map(|_| rng.uniform(0.4, 2.5) as f32).collect();
            let got = kernels::value_sum_f32(kind, &y, &alpha);
            let want = lane_tree_f32(kind, &y, &alpha);
            assert!(
                got == want,
                "{} n={n}: {got:?} vs f32 lane tree {want:?}",
                kind.name()
            );
            let scale = 0.75f32;
            let mut out = vec![f32::NAN; n];
            kernels::grad_into_f32(kind, &y, &alpha, scale, &mut out);
            for i in 0..n {
                let w = scale * grad_f32(kind, y[i], alpha[i]);
                assert!(out[i] == w, "{} grad_f32 n={n} i={i}", kind.name());
            }
            let mut asc = y.clone();
            kernels::ascend_slice_f32(kind, &mut asc, &alpha, scale);
            for i in 0..n {
                let w = y[i] + scale * grad_f32(kind, y[i], alpha[i]);
                assert!(asc[i] == w, "{} ascend_f32 n={n} i={i}", kind.name());
            }
            // the f32 lane sum tracks the sequential f32 reference at
            // f32 rounding noise
            let seq = kernels::value_sum_f32_ref(kind, &y, &alpha);
            assert!(
                (got - seq).abs() <= 1e-5 * (1.0 + seq.abs()),
                "{} n={n}: f32 lane {got} vs sequential {seq}",
                kind.name()
            );
        }
    }
}

#[test]
fn kind_batched_reward_runs_through_the_kernel_layer() {
    // end-to-end seam: slot_reward_kinds (value_sum over KindIndex runs
    // + accumulate quota) equals the per-coordinate scalar reference
    // within rounding — unchanged semantics under the §Perf-5 layer
    use ogasched::config::Scenario;
    use ogasched::reward::{slot_reward, slot_reward_kinds};
    use ogasched::traces::synthesize;
    let p = synthesize(&Scenario::small());
    let mut rng = Rng::new(8);
    let y: Vec<f64> = (0..p.decision_len()).map(|_| rng.uniform(0.0, 1.0)).collect();
    let x: Vec<f64> = (0..p.num_ports())
        .map(|_| if rng.bernoulli(0.6) { 1.0 } else { 0.0 })
        .collect();
    let a = slot_reward(&p, &x, &y);
    let mut quota = vec![0.0; p.num_resources];
    let b = slot_reward_kinds(&p, p.kinds(), &x, &y, &mut quota);
    assert!((a.q - b.q).abs() <= 1e-9 * (1.0 + a.q.abs()));
    assert!((a.gain - b.gain).abs() <= 1e-9 * (1.0 + a.gain.abs()));
    assert!((a.penalty - b.penalty).abs() <= 1e-9 * (1.0 + a.penalty.abs()));
}
