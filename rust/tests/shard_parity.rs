//! Sharded-vs-serial leader parity (§Perf-3): a `ShardedLeader` run —
//! per-shard policy ascent/projection, worker-owned ledger shards,
//! merged commit reports, parallel per-port reward — must reproduce the
//! serial `Leader` run **bit for bit**: every slot record (q, gain,
//! penalty), the cumulative reward, the clamp counts, the final ledger
//! (remaining capacity per (r, k)) and, for the learning policies, the
//! final decision tensor.  Across the full policy lineup × shard counts
//! {1, 2, 3, 7} × sparse and dense arrivals, on random bipartite
//! problems.
//!
//! This works because the sharded pipeline never re-associates a
//! floating-point reduction: per-coordinate math runs through the same
//! kernels on disjoint shard-owned coordinates, and every merge (per-
//! port rewards, ledger Σ deltas, full-sweep re-sums) is replayed
//! serially in the serial code's order.

use ogasched::coordinator::{Leader, ShardPlan, ShardedLeader};
use ogasched::ExecBudget;
use ogasched::graph::Bipartite;
use ogasched::model::Problem;
use ogasched::oga::utilities::UtilityKind;
use ogasched::schedulers::{
    BinPacking, Drf, Fairness, OgaMirror, OgaSched, Policy, RandomAlloc, Spreading,
};
use ogasched::sim::arrivals::Bernoulli;
use ogasched::utils::prop::{check, ensure, Size};
use ogasched::utils::rng::Rng;

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];

fn random_problem(rng: &mut Rng, size: Size) -> Problem {
    let l_n = rng.range(1, size.dim(6, 1));
    let r_n = rng.range(1, size.dim(16, 1));
    let k_n = rng.range(1, size.dim(4, 1));
    let p = rng.uniform(0.1, 0.9);
    let mut edges = Vec::new();
    for l in 0..l_n {
        for r in 0..r_n {
            if rng.bernoulli(p) {
                edges.push((l, r));
            }
        }
    }
    let graph = Bipartite::from_edges(l_n, r_n, &edges);
    Problem::new(
        graph,
        k_n,
        (0..l_n * k_n).map(|_| rng.uniform(0.2, 3.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 4.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 2.0)).collect(),
        (0..r_n * k_n).map(|_| UtilityKind::ALL[rng.below(4)]).collect(),
        (0..k_n).map(|_| rng.uniform(0.1, 0.8)).collect(),
    )
}

/// Fresh policy #i — the paper lineup plus both OGA scoring modes, the
/// mirror variant, and the random floor.
fn make_policy(p: &Problem, i: usize, seed: u64) -> (&'static str, Box<dyn Policy + Send>) {
    match i {
        0 => ("oga-reactive", Box::new(OgaSched::new(p, 2.0, 0.999, ExecBudget::auto()))),
        1 => ("oga-reservation", Box::new(OgaSched::reservation(p, 2.0, 0.999, ExecBudget::auto()))),
        2 => ("oga-mirror", Box::new(OgaMirror::new(p, 2.0, 0.999, ExecBudget::auto()))),
        3 => ("drf", Box::new(Drf::new())),
        4 => ("fairness", Box::new(Fairness::new())),
        5 => ("binpacking", Box::new(BinPacking::new())),
        6 => ("spreading", Box::new(Spreading::new())),
        _ => ("random", Box::new(RandomAlloc::new(seed))),
    }
}

const N_POLICIES: usize = 8;

#[test]
fn sharded_leader_matches_serial_bitwise() {
    check("shard-parity", 10, |rng, size| {
        let p = random_problem(rng, size);
        let horizon = 30;
        let arrival_seed = rng.below(1 << 30) as u64;
        let policy_seed = rng.below(1 << 30) as u64;
        for &rho in &[0.1, 0.8] {
            for i in 0..N_POLICIES {
                let (name, mut pol) = make_policy(&p, i, policy_seed);
                let serial = {
                    let mut leader = Leader::new(&p);
                    let mut arr = Bernoulli::uniform(p.num_ports(), rho, arrival_seed);
                    leader.run(pol.as_mut(), &mut arr, horizon)
                };
                for &shards in &SHARD_COUNTS {
                    let (_, mut pol) = make_policy(&p, i, policy_seed);
                    let mut leader = ShardedLeader::new(&p, shards);
                    let mut arr = Bernoulli::uniform(p.num_ports(), rho, arrival_seed);
                    let run = leader.run(pol.as_mut(), &mut arr, horizon);
                    let ctx = format!("{name} rho={rho} shards={shards}");
                    ensure(run.cumulative_reward == serial.cumulative_reward, || {
                        format!(
                            "{ctx}: cumulative {} vs serial {}",
                            run.cumulative_reward, serial.cumulative_reward
                        )
                    })?;
                    ensure(run.clamped_total == serial.clamped_total, || {
                        format!("{ctx}: clamped totals diverged")
                    })?;
                    for (a, b) in run.records.iter().zip(&serial.records) {
                        ensure(
                            a.q == b.q && a.gain == b.gain && a.penalty == b.penalty,
                            || {
                                format!(
                                    "{ctx} t={}: ({}, {}, {}) vs ({}, {}, {})",
                                    a.t, a.q, a.gain, a.penalty, b.q, b.gain, b.penalty
                                )
                            },
                        )?;
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_ledger_matches_serial_ledger() {
    // after identical runs, remaining capacity must agree exactly on
    // every (r, k) — the folded shard rows ARE the serial ledger rows
    check("shard-ledger-parity", 8, |rng, size| {
        let p = random_problem(rng, size);
        let horizon = 25;
        let seed = rng.below(1 << 30) as u64;
        for i in [0, 2, 4, 5] {
            let (name, mut pol) = make_policy(&p, i, seed);
            let mut serial = Leader::new(&p);
            let mut arr = Bernoulli::uniform(p.num_ports(), 0.5, seed);
            serial.run(pol.as_mut(), &mut arr, horizon);
            for &shards in &SHARD_COUNTS {
                let (_, mut pol) = make_policy(&p, i, seed);
                let mut sharded = ShardedLeader::new(&p, shards);
                let mut arr = Bernoulli::uniform(p.num_ports(), 0.5, seed);
                sharded.run(pol.as_mut(), &mut arr, horizon);
                sharded.state().check_conservation().map_err(|e| {
                    format!("{name} shards={shards}: conservation: {e}")
                })?;
                for r in 0..p.num_instances() {
                    for k in 0..p.num_resources {
                        ensure(
                            sharded.state().remaining_at(r, k)
                                == serial.state().remaining_at(r, k),
                            || {
                                format!(
                                    "{name} shards={shards}: remaining({r},{k}) {} vs {}",
                                    sharded.state().remaining_at(r, k),
                                    serial.state().remaining_at(r, k)
                                )
                            },
                        )?;
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_decisions_match_serial_bitwise() {
    // the learning policies' internal state (the decision tensor y)
    // after a sharded run equals the serial trajectory exactly — the
    // per-shard ascent/projection changed who computes each coordinate,
    // never its value
    let mut rng = Rng::new(4242);
    let p = random_problem(&mut rng, Size { scale: 1.0 });
    let horizon = 40;
    let serial_y = {
        let mut pol = OgaSched::new(&p, 2.0, 0.999, ExecBudget::auto());
        let mut leader = Leader::new(&p);
        let mut arr = Bernoulli::uniform(p.num_ports(), 0.3, 17);
        leader.run(&mut pol, &mut arr, horizon);
        pol.current_decision().to_vec()
    };
    for &shards in &SHARD_COUNTS {
        let mut pol = OgaSched::new(&p, 2.0, 0.999, ExecBudget::auto());
        let mut leader = ShardedLeader::new(&p, shards);
        let mut arr = Bernoulli::uniform(p.num_ports(), 0.3, 17);
        leader.run(&mut pol, &mut arr, horizon);
        assert_eq!(
            pol.current_decision(),
            &serial_y[..],
            "decision tensors diverged at shards={shards}"
        );
    }
}

#[test]
fn shard_plan_balances_random_problems() {
    check("shard-plan-balance", 40, |rng, size| {
        let p = random_problem(rng, size);
        for &shards in &SHARD_COUNTS {
            let plan = ShardPlan::build(&p, shards);
            plan.validate(&p).map_err(|e| format!("shards={shards}: {e}"))?;
            let s_n = plan.num_shards();
            let total: u64 = (0..s_n).map(|s| plan.load(s)).sum();
            let max_load = (0..s_n).map(|s| plan.load(s)).max().unwrap_or(0);
            let max_w = (0..p.num_instances())
                .map(|r| p.graph.instance_degree(r) as u64 * p.num_resources as u64)
                .max()
                .unwrap_or(0);
            // greedy-LPT guarantee
            ensure(max_load <= total / s_n as u64 + max_w, || {
                format!(
                    "shards={shards}: max load {max_load} over bound (total {total}, \
                     w* {max_w})"
                )
            })?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// §Perf-4: hierarchical execution budgets.  A lineup of sharded leaders
// under any `runs × shards` split must reproduce the serial lineup
// exactly, the budget-granted nested scatters must actually execute on
// group workers (never silently degrade to inline), and the sharded
// Eq. 50 oracle path (offline `solve_oracle` + the oracle-rate
// `OgaState::step` inside a sharded leader) must match its serial
// counterpart bitwise.

const BUDGET_SPLITS: [(usize, usize); 3] = [(1, 4), (2, 2), (4, 1)];

fn fresh_lineup(p: &Problem, seed: u64) -> Vec<Box<dyn Policy + Send>> {
    (0..N_POLICIES).map(|i| make_policy(p, i, seed).1).collect()
}

#[test]
fn budgeted_lineup_matches_serial_run_lineup() {
    use ogasched::config::Scenario;
    use ogasched::coordinator::run_lineup;
    use ogasched::traces::synthesize;
    use ogasched::utils::pool;

    // fixed small cluster (|R| = 16) so every split's shard plan has
    // real multi-instance shards and the scatter assertion below is
    // meaningful
    let p = synthesize(&Scenario::small());
    let horizon = 25;
    for &rho in &[0.1, 0.8] {
        let arrival_seed = 4242u64;
        let make_arrivals =
            || -> Box<dyn ogasched::sim::arrivals::ArrivalModel> {
                Box::new(Bernoulli::uniform(p.num_ports(), rho, arrival_seed))
            };

        let mut serial_lineup = fresh_lineup(&p, 7);
        let serial =
            run_lineup(&p, &mut serial_lineup, make_arrivals, horizon, ExecBudget::serial());

        for (runs, shards) in BUDGET_SPLITS {
            let scatters_before = pool::group_scatter_count();
            let mut lineup = fresh_lineup(&p, 7);
            let results = run_lineup(
                &p,
                &mut lineup,
                make_arrivals,
                horizon,
                ExecBudget::split(runs, shards),
            );
            assert_eq!(results.len(), serial.len());
            for (run, want) in results.iter().zip(&serial) {
                let ctx = format!("{} rho={rho} split {runs}x{shards}", run.policy);
                assert_eq!(run.policy, want.policy, "{ctx}");
                assert_eq!(run.cumulative_reward, want.cumulative_reward, "{ctx}");
                assert_eq!(run.clamped_total, want.clamped_total, "{ctx}");
                for (a, b) in run.records.iter().zip(&want.records) {
                    assert!(
                        a.q == b.q
                            && a.gain == b.gain
                            && a.penalty == b.penalty
                            && a.arrivals == b.arrivals,
                        "{ctx} t={}: record diverged",
                        a.t
                    );
                }
            }
            if shards > 1 {
                // the budget granted nested workers: the within-run shard
                // scatters must have dispatched onto the leased groups, not
                // silently degraded to inline execution
                assert!(
                    pool::group_scatter_count() > scatters_before,
                    "rho={rho} split {runs}x{shards}: no nested scatter reached a shard group"
                );
            }
        }
    }
}

#[test]
fn budgeted_lineup_ledgers_and_decisions_match_serial() {
    use ogasched::utils::pool;
    use std::sync::Arc;

    use ogasched::config::Scenario;
    use ogasched::traces::synthesize;
    let p = synthesize(&Scenario::small());
    let horizon = 30;
    let n_runs = 4usize;
    let k_n = p.num_resources;

    // serial reference: fresh OGASCHED per lane through the plain leader
    let serial: Vec<(Vec<f64>, Vec<f64>)> = (0..n_runs)
        .map(|i| {
            let mut pol = OgaSched::new(&p, 2.0, 0.999, ExecBudget::auto());
            let mut leader = Leader::new(&p);
            let mut arr = Bernoulli::uniform(p.num_ports(), 0.5, 99 + i as u64);
            leader.run(&mut pol, &mut arr, horizon);
            let remaining: Vec<f64> = (0..p.num_instances())
                .flat_map(|r| (0..k_n).map(move |k| (r, k)))
                .map(|(r, k)| leader.state().remaining_at(r, k))
                .collect();
            (remaining, pol.current_decision().to_vec())
        })
        .collect();

    for (runs, shards) in BUDGET_SPLITS {
        let plan = Arc::new(ShardPlan::build(&p, shards));
        let mut policies: Vec<OgaSched> = (0..n_runs)
            .map(|_| OgaSched::new(&p, 2.0, 0.999, ExecBudget::auto()))
            .collect();
        let budget = ExecBudget::split(runs, shards);
        let outs: Vec<Vec<f64>> = pool::scatter_runs(&mut policies, budget, |i, pol| {
            let mut leader = ShardedLeader::with_plan(&p, Arc::clone(&plan));
            let mut arr = Bernoulli::uniform(p.num_ports(), 0.5, 99 + i as u64);
            leader.run(pol, &mut arr, horizon);
            (0..p.num_instances())
                .flat_map(|r| (0..k_n).map(move |k| (r, k)))
                .map(|(r, k)| leader.state().remaining_at(r, k))
                .collect()
        });
        for i in 0..n_runs {
            let ctx = format!("lane {i} split {runs}x{shards}");
            assert_eq!(outs[i], serial[i].0, "{ctx}: ledgers diverged");
            assert_eq!(
                policies[i].current_decision(),
                &serial[i].1[..],
                "{ctx}: decision tensors diverged"
            );
        }
    }
}

#[test]
fn sharded_solve_oracle_matches_serial_bitwise() {
    // §Perf-4/§Perf-5: the sharded solve fans out the gradient fill
    // (phase-A per-port reductions included), ascent, projection AND
    // the per-iteration objective; y* and the objective (which is now
    // itself the sharded evaluation) must equal the serial solve
    // exactly, across plain shard counts and runs×shards budget splits.
    use ogasched::regret::{arrival_counts, solve_oracle};
    use ogasched::sim::arrivals::record_trajectory;

    use ogasched::config::Scenario;
    use ogasched::traces::synthesize;
    let p = synthesize(&Scenario::small());
    let horizon = 40;
    let mut src = Bernoulli::uniform(p.num_ports(), 0.6, 31);
    let traj = record_trajectory(&mut src, p.num_ports(), horizon);
    let counts = arrival_counts(&traj, p.num_ports());

    let serial = solve_oracle(&p, &counts, 60, ExecBudget::serial());
    for shards in SHARD_COUNTS {
        let sharded = solve_oracle(&p, &counts, 60, ExecBudget::shards_only(shards));
        assert_eq!(
            sharded.cumulative_reward, serial.cumulative_reward,
            "shards={shards}: objective diverged"
        );
        assert_eq!(sharded.y_star, serial.y_star, "shards={shards}: y* diverged");
    }
    for (runs, shards) in BUDGET_SPLITS {
        let sharded = solve_oracle(&p, &counts, 60, ExecBudget::split(runs, shards));
        assert_eq!(
            sharded.cumulative_reward, serial.cumulative_reward,
            "split {runs}x{shards}: objective diverged"
        );
        assert_eq!(
            sharded.y_star, serial.y_star,
            "split {runs}x{shards}: y* diverged"
        );
    }
}

#[test]
fn sharded_objective_matches_serial_bitwise() {
    // §Perf-5: the pool-scattered slot_reward_ports_sharded — per-port
    // kernels fan out, components merge in ascending port order — must
    // equal slot_reward_kinds bit for bit on random problems, decisions
    // and (sparse, dense, multi-count) arrival vectors.
    use ogasched::model::KindIndex;
    use ogasched::reward::{
        slot_reward_kinds, slot_reward_ports_sharded, PortRewardScratch,
    };
    check("sharded-objective-parity", 20, |rng, size| {
        let p = random_problem(rng, size);
        let kinds = KindIndex::build(&p);
        let y: Vec<f64> =
            (0..p.decision_len()).map(|_| rng.uniform(0.0, 2.5)).collect();
        for &rho in &[0.15, 0.6, 1.0] {
            let counts: Vec<f64> = (0..p.num_ports())
                .map(|_| {
                    if rng.bernoulli(rho) {
                        (1 + rng.below(60)) as f64
                    } else {
                        0.0
                    }
                })
                .collect();
            let arrived: Vec<usize> =
                (0..p.num_ports()).filter(|&l| counts[l] != 0.0).collect();
            let mut quota = vec![0.0; p.num_resources];
            let want = slot_reward_kinds(&p, &kinds, &counts, &y, &mut quota);
            for &workers in &SHARD_COUNTS {
                let mut scratch = PortRewardScratch::default();
                let got = slot_reward_ports_sharded(
                    &p, &kinds, &counts, &y, &arrived, workers, &mut scratch,
                );
                ensure(got == want, || {
                    format!(
                        "rho={rho} workers={workers}: ({}, {}, {}) vs \
                         ({}, {}, {})",
                        got.q, got.gain, got.penalty, want.q, want.gain, want.penalty
                    )
                })?;
            }
        }
        Ok(())
    });
}

#[test]
fn oracle_rate_sharded_leader_matches_serial() {
    // the online half of the Eq. 50 path: OGASCHED with the oracle
    // learning rate driven by a ShardedLeader — its two-pass
    // gradient/‖∇q‖/ascent runs per shard with the norm replayed
    // serially, so records and decisions stay bit-identical
    use ogasched::config::Scenario;
    use ogasched::traces::synthesize;
    let p = synthesize(&Scenario::small());
    let horizon = 30;
    let serial = {
        let mut pol = OgaSched::with_oracle_rate(&p, horizon, ExecBudget::auto());
        let mut leader = Leader::new(&p);
        let mut arr = Bernoulli::uniform(p.num_ports(), 0.5, 61);
        let run = leader.run(&mut pol, &mut arr, horizon);
        (run, pol.current_decision().to_vec())
    };
    for shards in SHARD_COUNTS {
        let mut pol = OgaSched::with_oracle_rate(&p, horizon, ExecBudget::auto());
        let mut leader = ShardedLeader::new(&p, shards);
        let mut arr = Bernoulli::uniform(p.num_ports(), 0.5, 61);
        let run = leader.run(&mut pol, &mut arr, horizon);
        assert_eq!(
            run.cumulative_reward, serial.0.cumulative_reward,
            "shards={shards}"
        );
        for (a, b) in run.records.iter().zip(&serial.0.records) {
            assert!(
                a.q == b.q && a.gain == b.gain && a.penalty == b.penalty,
                "shards={shards} t={}: record diverged",
                a.t
            );
        }
        assert_eq!(
            pol.current_decision(),
            &serial.1[..],
            "shards={shards}: oracle-rate decision tensors diverged"
        );
    }
}
