//! Layout parity: the edge-major CSR hot path must agree coordinate-wise
//! with the dense [L, R, K] reference implementation (`oga::dense_ref`,
//! the seed's layout) on random bipartite graphs — including ports with
//! zero instances, isolated instances, and fully-connected graphs.
//!
//! Each property draws a random problem (random edge set, demands,
//! capacities, utility families, betas), runs both layouts, and compares
//! through the edge maps.  This is the correctness seam of the sparse
//! refactor: gradient, fused ascent, projection, the dirty-tracking full
//! step, and the slot reward are each pinned to the dense oracle.

use ogasched::graph::Bipartite;
use ogasched::ExecBudget;
use ogasched::model::{KindIndex, Problem};
use ogasched::oga::dense_ref::{
    self, dense_idx, dense_len, fused_ascent_dense, gradient_dense, project_dense_serial,
    slot_reward_dense, DenseOgaState,
};
use ogasched::oga::gradient::{gradient, gradient_sparse, GradScratch};
use ogasched::oga::projection::project;
use ogasched::oga::utilities::UtilityKind;
use ogasched::oga::{LearningRate, OgaState};
use ogasched::reward::{slot_reward, slot_reward_kinds};
use ogasched::utils::prop::{check, ensure, Size};
use ogasched::utils::rng::Rng;

/// Random problem over a random bipartite graph.  With probability ~0.15
/// the graph is complete; otherwise edges are Bernoulli so some ports
/// and instances may have zero edges.
fn random_problem(rng: &mut Rng, size: Size) -> Problem {
    let l_n = rng.range(1, size.dim(8, 1));
    let r_n = rng.range(1, size.dim(20, 1));
    let k_n = rng.range(1, size.dim(5, 1));
    let graph = if rng.bernoulli(0.15) {
        Bipartite::full(l_n, r_n)
    } else {
        let p = rng.uniform(0.05, 0.8);
        let mut edges = Vec::new();
        for l in 0..l_n {
            for r in 0..r_n {
                if rng.bernoulli(p) {
                    edges.push((l, r));
                }
            }
        }
        // deliberately allow stranded ports/instances (zero-degree)
        Bipartite::from_edges(l_n, r_n, &edges)
    };
    let kinds = [
        UtilityKind::Linear,
        UtilityKind::Log,
        UtilityKind::Poly,
        UtilityKind::Reciprocal,
    ];
    Problem::new(
        graph,
        k_n,
        (0..l_n * k_n).map(|_| rng.uniform(0.2, 4.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 8.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 2.0)).collect(),
        (0..r_n * k_n).map(|_| kinds[rng.below(kinds.len())]).collect(),
        (0..k_n).map(|_| rng.uniform(0.0, 1.0)).collect(),
    )
}

fn random_arrivals(rng: &mut Rng, p: &Problem) -> Vec<f64> {
    (0..p.num_ports())
        .map(|_| {
            if rng.bernoulli(0.6) {
                // include multi-arrival counts (Sec. 3.4)
                rng.range(1, 3) as f64
            } else {
                0.0
            }
        })
        .collect()
}

fn random_decision(rng: &mut Rng, p: &Problem, lo: f64, hi: f64) -> Vec<f64> {
    (0..p.decision_len()).map(|_| rng.uniform(lo, hi)).collect()
}

/// Compare a CSR tensor against a dense tensor through the edge maps;
/// also require the dense off-edge coordinates to equal `off_edge`.
fn compare_layouts(
    p: &Problem,
    csr: &[f64],
    dense: &[f64],
    off_edge: Option<f64>,
    tol: f64,
    what: &str,
) -> Result<(), String> {
    let k_n = p.num_resources;
    for e in 0..p.num_edges() {
        let l = p.graph.edge_port[e];
        let r = p.graph.edge_instance[e];
        for k in 0..k_n {
            let a = csr[e * k_n + k];
            let b = dense[dense_idx(p, l, r, k)];
            ensure((a - b).abs() <= tol, || {
                format!("{what}: csr={a} dense={b} at (l={l},r={r},k={k})")
            })?;
        }
    }
    if let Some(want) = off_edge {
        for l in 0..p.num_ports() {
            for r in 0..p.num_instances() {
                if p.graph.has_edge(l, r) {
                    continue;
                }
                for k in 0..k_n {
                    let v = dense[dense_idx(p, l, r, k)];
                    ensure((v - want).abs() <= tol, || {
                        format!("{what}: dense off-edge ({l},{r},{k}) = {v}, want {want}")
                    })?;
                }
            }
        }
    }
    Ok(())
}

#[test]
fn gradient_matches_dense_reference() {
    // the CSR gradient is now kind-batched (KindIndex runs + a separate
    // penalty-lane pass); the dense reference keeps the seed's scalar
    // per-coordinate form, so this also pins the kind-batched kernels
    // on mixed-utility problems
    check("parity-gradient", 120, |rng, size| {
        let p = random_problem(rng, size);
        let kinds = KindIndex::build(&p);
        kinds.validate(&p).map_err(|e| format!("kind index: {e}"))?;
        let x = random_arrivals(rng, &p);
        let y = random_decision(rng, &p, 0.0, 3.0);
        let y_dense = dense_ref::to_dense(&p, &y);
        let mut g_csr = vec![1.0; p.decision_len()];
        gradient(&p, &kinds, &x, &y, &mut g_csr, &mut GradScratch::default());
        let mut g_dense = vec![1.0; dense_len(&p)];
        gradient_dense(&p, &x, &y_dense, &mut g_dense);
        compare_layouts(&p, &g_csr, &g_dense, Some(0.0), 1e-12, "gradient")
    });
}

#[test]
fn sparse_gradient_matches_dense_reference_across_slots() {
    // gradient_sparse keeps state (the previously filled slices) across
    // calls; over a sequence of changing arrival sets it must stay
    // equal to the memset-based dense reference every slot
    check("parity-gradient-sparse", 60, |rng, size| {
        let p = random_problem(rng, size);
        let kinds = KindIndex::build(&p);
        let mut g_csr = vec![0.0; p.decision_len()];
        let mut active = Vec::new();
        let mut scratch = GradScratch::default();
        for t in 0..5 {
            let x = random_arrivals(rng, &p);
            let y = random_decision(rng, &p, 0.0, 3.0);
            gradient_sparse(&p, &kinds, &x, &y, &mut g_csr, &mut scratch, &mut active);
            let mut g_dense = vec![1.0; dense_len(&p)];
            gradient_dense(&p, &x, &dense_ref::to_dense(&p, &y), &mut g_dense);
            compare_layouts(
                &p,
                &g_csr,
                &g_dense,
                Some(0.0),
                1e-12,
                &format!("sparse gradient t={t}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn fused_ascent_matches_dense_reference() {
    check("parity-fused-ascent", 120, |rng, size| {
        let p = random_problem(rng, size);
        let x = random_arrivals(rng, &p);
        let eta = rng.uniform(0.01, 5.0);
        let y0 = random_decision(rng, &p, 0.0, 2.0);
        let mut y_dense = dense_ref::to_dense(&p, &y0);
        fused_ascent_dense(&p, &x, eta, &mut y_dense);
        let mut state = OgaState::new(&p, LearningRate::Constant(eta), ExecBudget::auto());
        state.y.copy_from_slice(&y0);
        state.fused_ascent(&p, &x, eta);
        compare_layouts(&p, &state.y, &y_dense, Some(0.0), 1e-12, "fused ascent")
    });
}

#[test]
fn projection_matches_dense_reference() {
    check("parity-projection", 120, |rng, size| {
        let p = random_problem(rng, size);
        // negatives + above-cap values exercise every projection regime
        let z = random_decision(rng, &p, -2.0, 8.0);
        let mut z_csr = z.clone();
        project(&p, &mut z_csr, 0);
        let mut z_dense = dense_ref::to_dense(&p, &z);
        // plant garbage off-edge to prove the dense path re-zeroes it
        // while the CSR path has nothing to re-zero
        for l in 0..p.num_ports() {
            for r in 0..p.num_instances() {
                if !p.graph.has_edge(l, r) {
                    for k in 0..p.num_resources {
                        z_dense[dense_idx(&p, l, r, k)] = rng.uniform(-3.0, 3.0);
                    }
                }
            }
        }
        project_dense_serial(&p, &mut z_dense);
        compare_layouts(&p, &z_csr, &z_dense, Some(0.0), 1e-9, "projection")?;
        p.check_feasible(&z_csr, 1e-7).map_err(|e| e.to_string())
    });
}

#[test]
fn slot_reward_matches_dense_reference() {
    // both the plain scratch form and the kind-batched hot-path form
    // are pinned to the dense oracle on mixed-utility problems
    check("parity-reward", 120, |rng, size| {
        let p = random_problem(rng, size);
        let kinds = KindIndex::build(&p);
        let x = random_arrivals(rng, &p);
        let y = random_decision(rng, &p, 0.0, 2.0);
        let y_dense = dense_ref::to_dense(&p, &y);
        let a = slot_reward(&p, &x, &y);
        let b = slot_reward_dense(&p, &x, &y_dense);
        let mut quota = vec![0.0; p.num_resources];
        let c = slot_reward_kinds(&p, &kinds, &x, &y, &mut quota);
        ensure((a.q - b.q).abs() < 1e-9, || format!("q: {} vs {}", a.q, b.q))?;
        ensure((a.gain - b.gain).abs() < 1e-9, || {
            format!("gain: {} vs {}", a.gain, b.gain)
        })?;
        ensure((a.penalty - b.penalty).abs() < 1e-9, || {
            format!("penalty: {} vs {}", a.penalty, b.penalty)
        })?;
        let tol = 1e-9 * (1.0 + b.gain.abs());
        ensure((c.q - b.q).abs() < tol, || {
            format!("kind-batched q: {} vs {}", c.q, b.q)
        })?;
        ensure((c.gain - b.gain).abs() < tol, || {
            format!("kind-batched gain: {} vs {}", c.gain, b.gain)
        })?;
        ensure((c.penalty - b.penalty).abs() < tol, || {
            format!("kind-batched penalty: {} vs {}", c.penalty, b.penalty)
        })
    });
}

#[test]
fn full_step_trajectory_matches_dense_reference() {
    // The end-to-end check: dirty-instance tracking + subset projection
    // over several slots must equal the dense full-projection step.
    check("parity-step-trajectory", 40, |rng, size| {
        let p = random_problem(rng, size);
        let eta = rng.uniform(0.05, 2.0);
        let mut csr = OgaState::new(&p, LearningRate::Constant(eta), ExecBudget::auto());
        let mut dense = DenseOgaState::new(&p, 1);
        for t in 0..6 {
            let x = random_arrivals(rng, &p);
            csr.step(&p, &x);
            dense.step(&p, &x, eta);
            compare_layouts(
                &p,
                &csr.y,
                &dense.y,
                Some(0.0),
                1e-9,
                &format!("step t={t}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn full_graph_parity_smoke() {
    // fully-connected graph: CSR edge ids coincide with dense (l·R + r)
    // ordering, so the tensors must be bit-identical after projection
    let mut rng = Rng::new(99);
    let p = Problem::new(
        Bipartite::full(5, 7),
        3,
        (0..5 * 3).map(|_| rng.uniform(0.5, 2.0)).collect(),
        (0..7 * 3).map(|_| rng.uniform(1.0, 4.0)).collect(),
        vec![1.0; 21],
        vec![UtilityKind::Linear; 21],
        vec![0.3, 0.4, 0.5],
    );
    assert_eq!(p.decision_len(), dense_len(&p));
    let z: Vec<f64> = (0..p.decision_len()).map(|_| rng.uniform(-1.0, 5.0)).collect();
    let mut z_csr = z.clone();
    let mut z_dense = z;
    project(&p, &mut z_csr, 0);
    project_dense_serial(&p, &mut z_dense);
    assert_eq!(z_csr, z_dense);
}

#[test]
fn zero_degree_port_contributes_nothing() {
    // a port with no instances has no coordinates, no gradient, and no
    // reward — and must not break any stage
    let graph = Bipartite::from_edges(3, 2, &[(0, 0), (2, 1)]); // port 1 stranded
    let p = Problem::new(
        graph,
        2,
        vec![1.0; 6],
        vec![2.0; 4],
        vec![1.0; 4],
        vec![UtilityKind::Linear; 4],
        vec![0.4, 0.6],
    );
    assert_eq!(p.decision_len(), 2 * 2);
    let x = vec![1.0, 1.0, 1.0];
    let mut state = OgaState::new(&p, LearningRate::Constant(0.5), ExecBudget::auto());
    for _ in 0..3 {
        state.step(&p, &x);
        p.check_feasible(&state.y, 1e-9).unwrap();
    }
    let r = slot_reward(&p, &x, &state.y);
    let r_dense = slot_reward_dense(&p, &x, &dense_ref::to_dense(&p, &state.y));
    assert!((r.q - r_dense.q).abs() < 1e-12);
}
