//! Incremental-ledger parity (§Perf-2): `ClusterState::commit_instances`
//! driven by random dirty-set commit/release sequences must agree with
//! the full-sweep `ClusterState::commit` oracle — clamped counts and the
//! mutated decision tensor bit-for-bit, remaining capacities bit-for-bit
//! on every (r, k), committed units up to summation-order rounding (the
//! incremental path maintains Σ usage by deltas; exact re-summation is
//! precisely the O(R·K) pass being removed).
//!
//! A second suite checks the seam end to end: a `Leader` run with the
//! policies' `Touched` reporting enabled must reproduce the exact slot
//! records of the same run forced through the full-sweep commit.

use ogasched::coordinator::{ClusterState, Leader};
use ogasched::ExecBudget;
use ogasched::graph::Bipartite;
use ogasched::model::Problem;
use ogasched::oga::utilities::UtilityKind;
use ogasched::schedulers::{
    BinPacking, Drf, Fairness, OgaMirror, OgaSched, Policy, RandomAlloc, Spreading,
};
use ogasched::sim::arrivals::Bernoulli;
use ogasched::utils::prop::{check, ensure, Size};
use ogasched::utils::rng::Rng;

fn random_problem(rng: &mut Rng, size: Size) -> Problem {
    let l_n = rng.range(1, size.dim(6, 1));
    let r_n = rng.range(1, size.dim(16, 1));
    let k_n = rng.range(1, size.dim(4, 1));
    let p = rng.uniform(0.1, 0.9);
    let mut edges = Vec::new();
    for l in 0..l_n {
        for r in 0..r_n {
            if rng.bernoulli(p) {
                edges.push((l, r));
            }
        }
    }
    let graph = Bipartite::from_edges(l_n, r_n, &edges);
    Problem::new(
        graph,
        k_n,
        (0..l_n * k_n).map(|_| rng.uniform(0.2, 3.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 4.0)).collect(),
        (0..r_n * k_n).map(|_| rng.uniform(0.5, 2.0)).collect(),
        (0..r_n * k_n).map(|_| UtilityKind::ALL[rng.below(4)]).collect(),
        (0..k_n).map(|_| rng.uniform(0.1, 0.8)).collect(),
    )
}

#[test]
fn incremental_commit_matches_full_sweep_oracle() {
    check("ledger-incremental-vs-full", 80, |rng, size| {
        let p = random_problem(rng, size);
        let k_n = p.num_resources;
        let mut incr = ClusterState::new(&p);
        let mut y = vec![0.0; p.decision_len()];
        let slots = rng.range(3, 10);
        for t in 0..slots {
            // random dirty set; perturb ONLY those instances' columns
            // (the Touched::Instances contract), occasionally far past
            // capacity to force proportional clamps in both ledgers
            let mut dirty = Vec::new();
            for r in 0..p.num_instances() {
                if rng.bernoulli(0.35) {
                    dirty.push(r);
                }
            }
            for &r in &dirty {
                for &e in p.graph.instance_edge_ids(r) {
                    for k in 0..k_n {
                        let cap = p.capacity_at(r, k);
                        let v = if rng.bernoulli(0.15) {
                            rng.uniform(cap, 3.0 * cap) // overshoot
                        } else {
                            rng.uniform(0.0, 0.6 * cap)
                        };
                        y[e * k_n + k] = v;
                    }
                }
            }
            // oracle: a fresh ledger full-sweep over a copy of y
            let mut y_oracle = y.clone();
            let mut oracle = ClusterState::new(&p);
            let rep_full = oracle.commit(&p, &mut y_oracle);
            let rep_incr = incr.commit_instances(&p, &mut y, &dirty);
            ensure(y == y_oracle, || {
                format!("t={t}: clamped tensors diverged (dirty={dirty:?})")
            })?;
            ensure(rep_incr.clamped == rep_full.clamped, || {
                format!(
                    "t={t}: clamped {} vs oracle {}",
                    rep_incr.clamped, rep_full.clamped
                )
            })?;
            let tol = 1e-9 * (1.0 + rep_full.committed_units.abs());
            ensure(
                (rep_incr.committed_units - rep_full.committed_units).abs() <= tol,
                || {
                    format!(
                        "t={t}: committed units {} vs oracle {}",
                        rep_incr.committed_units, rep_full.committed_units
                    )
                },
            )?;
            for r in 0..p.num_instances() {
                for k in 0..k_n {
                    let a = incr.remaining_at(r, k);
                    let b = oracle.remaining_at(r, k);
                    ensure(a == b, || {
                        format!("t={t}: remaining({r},{k}) {a} vs oracle {b}")
                    })?;
                }
            }
            // NB: no check_conservation here — the commit clamp threshold
            // (cap·(1+1e-5)+1e-6, seed behavior) is looser than the
            // conservation tolerance (1e-9), so adversarial draws can
            // legitimately land between the two; parity with the oracle
            // is the property under test
            incr.release();
            // lazy release must still read full capacity everywhere
            for r in 0..p.num_instances() {
                for k in 0..k_n {
                    ensure(incr.remaining_at(r, k) == p.capacity_at(r, k), || {
                        format!("t={t}: released remaining({r},{k}) != capacity")
                    })?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn occasional_full_sweep_interleaves_with_incremental() {
    // mixing commit() and commit_instances() on one ledger (a policy
    // may alternate Touched::All / Touched::Instances) stays exact
    check("ledger-mixed-commits", 40, |rng, size| {
        let p = random_problem(rng, size);
        let k_n = p.num_resources;
        let mut incr = ClusterState::new(&p);
        let mut y = vec![0.0; p.decision_len()];
        for t in 0..8 {
            let mut dirty = Vec::new();
            for r in 0..p.num_instances() {
                if rng.bernoulli(0.4) {
                    dirty.push(r);
                }
            }
            for &r in &dirty {
                for &e in p.graph.instance_edge_ids(r) {
                    for k in 0..k_n {
                        y[e * k_n + k] = rng.uniform(0.0, p.capacity_at(r, k));
                    }
                }
            }
            if rng.bernoulli(0.4) {
                incr.commit(&p, &mut y);
            } else {
                incr.commit_instances(&p, &mut y, &dirty);
            }
            let mut y_oracle = y.clone();
            let mut oracle = ClusterState::new(&p);
            oracle.commit(&p, &mut y_oracle);
            for r in 0..p.num_instances() {
                for k in 0..k_n {
                    ensure(incr.remaining_at(r, k) == oracle.remaining_at(r, k), || {
                        format!("t={t}: remaining({r},{k}) diverged")
                    })?;
                }
            }
            incr.release();
        }
        Ok(())
    });
}

/// Wrapper that forwards a policy but hides its `Touched` reporting, so
/// the leader always takes the full-sweep commit path.
struct FullSweep<P: Policy>(P);

impl<P: Policy> Policy for FullSweep<P> {
    fn name(&self) -> &'static str {
        self.0.name()
    }
    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        self.0.decide(problem, x, y);
    }
    fn reset(&mut self, problem: &Problem) {
        self.0.reset(problem);
    }
    // touched(): default Touched::All
}

#[test]
fn leader_runs_identical_with_and_without_touched_reporting() {
    // End-to-end seam check on sparse arrivals: every policy's run
    // through the incremental commit path must reproduce the full-sweep
    // run record for record (bitwise — same decisions, same rewards).
    let mut rng = Rng::new(2024);
    let p = random_problem(&mut rng, Size { scale: 1.0 });
    let horizon = 60;
    let runs: Vec<(Box<dyn Policy>, Box<dyn Policy>)> = vec![
        (
            Box::new(OgaSched::new(&p, 2.0, 0.999, ExecBudget::auto())),
            Box::new(FullSweep(OgaSched::new(&p, 2.0, 0.999, ExecBudget::auto()))),
        ),
        (
            Box::new(OgaSched::reservation(&p, 2.0, 0.999, ExecBudget::auto())),
            Box::new(FullSweep(OgaSched::reservation(&p, 2.0, 0.999, ExecBudget::auto()))),
        ),
        (
            Box::new(OgaMirror::new(&p, 2.0, 0.999, ExecBudget::auto())),
            Box::new(FullSweep(OgaMirror::new(&p, 2.0, 0.999, ExecBudget::auto()))),
        ),
        (Box::new(Drf::new()), Box::new(FullSweep(Drf::new()))),
        (Box::new(Fairness::new()), Box::new(FullSweep(Fairness::new()))),
        (Box::new(BinPacking::new()), Box::new(FullSweep(BinPacking::new()))),
        (Box::new(Spreading::new()), Box::new(FullSweep(Spreading::new()))),
        (
            Box::new(RandomAlloc::new(7)),
            Box::new(FullSweep(RandomAlloc::new(7))),
        ),
    ];
    for (mut incr, mut full) in runs {
        let run_incr = {
            let mut leader = Leader::new(&p);
            let mut arr = Bernoulli::uniform(p.num_ports(), 0.1, 99);
            leader.run(incr.as_mut(), &mut arr, horizon)
        };
        let run_full = {
            let mut leader = Leader::new(&p);
            let mut arr = Bernoulli::uniform(p.num_ports(), 0.1, 99);
            leader.run(full.as_mut(), &mut arr, horizon)
        };
        assert_eq!(
            run_incr.cumulative_reward, run_full.cumulative_reward,
            "{}: cumulative reward diverged",
            run_incr.policy
        );
        assert_eq!(run_incr.clamped_total, run_full.clamped_total);
        for (a, b) in run_incr.records.iter().zip(&run_full.records) {
            assert_eq!(a.q, b.q, "{} t={}", run_incr.policy, a.t);
            assert_eq!(a.gain, b.gain);
            assert_eq!(a.penalty, b.penalty);
        }
    }
}
