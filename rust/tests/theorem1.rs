//! Property-level validation of Theorem 1 across random scenarios:
//! the realized regret of OGASCHED (Eq. 50 learning rate) never exceeds
//! H_G · √T, and the regret measured at growing horizons grows
//! sublinearly.  This is the theory contribution's empirical check.

use ogasched::config::Scenario;
use ogasched::ExecBudget;
use ogasched::coordinator::Leader;
use ogasched::regret::{arrival_counts, regret, solve_oracle, theorem1_bound};
use ogasched::schedulers::OgaSched;
use ogasched::sim::arrivals::{record_trajectory, Alternating, Bernoulli, Replay};
use ogasched::traces::synthesize;
use ogasched::utils::stats;

fn measure_regret(scenario: &Scenario, adversarial: bool) -> (f64, f64) {
    let p = synthesize(scenario);
    let traj = if adversarial {
        let mut src = Alternating::new(25);
        record_trajectory(&mut src, p.num_ports(), scenario.horizon)
    } else {
        let mut src =
            Bernoulli::uniform(p.num_ports(), scenario.arrival_prob, scenario.seed ^ 0xF00);
        record_trajectory(&mut src, p.num_ports(), scenario.horizon)
    };
    let counts = arrival_counts(&traj, p.num_ports());
    let oracle = solve_oracle(&p, &counts, 300, ExecBudget::serial());
    let mut leader = Leader::new(&p);
    let mut pol = OgaSched::with_oracle_rate(&p, scenario.horizon, ExecBudget::auto());
    let mut replay = Replay::new(traj);
    let run = leader.run(&mut pol, &mut replay, scenario.horizon);
    (regret(&oracle, run.cumulative_reward), theorem1_bound(&p, scenario.horizon))
}

#[test]
fn regret_below_bound_across_seeds() {
    for seed in [1u64, 7, 2023] {
        let mut s = Scenario::small();
        s.seed = seed;
        s.horizon = 200;
        let (r, bound) = measure_regret(&s, false);
        assert!(
            r <= bound,
            "seed {seed}: regret {r} exceeds Thm.1 bound {bound}"
        );
    }
}

#[test]
fn regret_below_bound_under_adversarial_arrivals() {
    // Eq. 11 takes a sup over trajectories; the alternating pattern is a
    // hard case for a stationary comparator's learner.
    let mut s = Scenario::small();
    s.horizon = 300;
    let (r, bound) = measure_regret(&s, true);
    assert!(r <= bound, "adversarial regret {r} exceeds bound {bound}");
}

#[test]
fn regret_growth_is_sublinear_in_t() {
    let horizons = [100usize, 200, 400, 800];
    let mut ts = Vec::new();
    let mut rs = Vec::new();
    for &t in &horizons {
        let mut s = Scenario::small();
        s.horizon = t;
        let (r, _) = measure_regret(&s, false);
        ts.push(t as f64);
        rs.push(r.max(1e-6));
    }
    let (_, exponent, _) = stats::powerlaw_fit(&ts, &rs);
    assert!(
        exponent < 1.0,
        "regret grows superlinearly: exponent {exponent}, points {rs:?}"
    );
}

#[test]
fn oracle_reward_at_least_online() {
    // By definition Q(y*) >= best stationary; it should be >= the online
    // cumulative reward minus numerical slack on stationary-ish arrivals.
    let mut s = Scenario::small();
    s.horizon = 250;
    let (r, _) = measure_regret(&s, false);
    assert!(r > -1e-6, "negative regret means the oracle under-solved: {r}");
}
