#!/usr/bin/env python3
"""Layout-speedup proxy for the Rust hot path (EXPERIMENTS.md §Perf).

The offline image this repo grows in ships no Rust toolchain, so the
`benches/hot_path.rs` numbers cannot be regenerated here.  This script
mirrors the two per-slot OGA step implementations *structurally 1:1*
(same loops, same operation counts, same channel projector) in pure
Python:

  * dense  — the seed's [L, R, K] layout: fused ascent over arrived
    ports, then a full projection that re-zeroes every off-edge
    coordinate of every instance (O(L*R*K)) and projects all R*K
    channels;
  * csr    — the edge-major [E, K] layout with dirty-instance tracking:
    fused ascent over arrived edge ranges, then projection of only the
    instances adjacent to arrived ports, with no off-edge coordinates to
    re-zero.

Because both sides pay identical interpreter overhead per primitive
operation, the dense/csr *ratio* approximates the Rust ratio of the same
loops (it excludes the seed's additional ~100us/worker thread::scope
spawn cost on the dense side, so it is a conservative lower bound for
the parallel path).  Regenerate the real numbers with
`cargo bench --bench hot_path` -> BENCH_hot_path.json once a toolchain
is available.
"""

import json
import random
import time


def make_problem(L, R, K, density, seed):
    rng = random.Random(seed)
    ports_to_instances = [[] for _ in range(L)]
    instances_to_ports = [[] for _ in range(R)]
    p = min(1.0, density / L)
    for r in range(R):
        any_edge = False
        for l in range(L):
            if rng.random() < p:
                ports_to_instances[l].append(r)
                instances_to_ports[r].append(l)
                any_edge = True
        if not any_edge:
            l = rng.randrange(L)
            ports_to_instances[l].append(r)
            instances_to_ports[r].append(l)
    for l in range(L):
        if not ports_to_instances[l]:
            r = rng.randrange(R)
            ports_to_instances[l].append(r)
            instances_to_ports[r].append(l)
            instances_to_ports[r].sort()
    # edge-major CSR index (port-major ids)
    port_ptr = [0]
    edge_instance = []
    edge_port = []
    for l in range(L):
        for r in sorted(ports_to_instances[l]):
            edge_instance.append(r)
            edge_port.append(l)
        port_ptr.append(len(edge_instance))
    instance_edges = [[] for _ in range(R)]
    for e, r in enumerate(edge_instance):
        instance_edges[r].append(e)
    has_edge = [[False] * R for _ in range(L)]
    for l in range(L):
        for r in ports_to_instances[l]:
            has_edge[l][r] = True
    demand = [[rng.uniform(0.5, 2.0) for _ in range(K)] for _ in range(L)]
    capacity = [[rng.uniform(2.0, 6.0) for _ in range(K)] for _ in range(R)]
    alpha = [[rng.uniform(1.0, 1.5) for _ in range(K)] for _ in range(R)]
    beta = [rng.uniform(0.3, 0.5) for _ in range(K)]
    return dict(L=L, R=R, K=K, ports_to_instances=ports_to_instances,
                instances_to_ports=instances_to_ports, port_ptr=port_ptr,
                edge_instance=edge_instance, edge_port=edge_port,
                instance_edges=instance_edges, has_edge=has_edge,
                demand=demand, capacity=capacity, alpha=alpha, beta=beta,
                E=len(edge_port))


def project_channel(vals, caps, capacity):
    """Shared O(n log n) event-sweep channel projector (both layouts)."""
    used = sum(min(max(z, 0.0), a) for z, a in zip(vals, caps))
    if used <= capacity:
        return [min(max(z, 0.0), a) for z, a in zip(vals, caps)]
    events = []
    for i, (z, a) in enumerate(zip(vals, caps)):
        if z > 0.0:
            events.append((z, 0, i))
        if z - a > 0.0:
            events.append((z - a, 1, i))
    events.sort(key=lambda t: -t[0])
    m = s = c = 0.0
    n_ev = len(events)
    idx = 0
    tau = 0.0
    while idx < n_ev:
        upper = events[idx][0]
        while idx < n_ev and events[idx][0] == upper:
            _, kind, i = events[idx]
            if kind == 0:
                m += 1.0
                s += vals[i]
            else:
                m -= 1.0
                s -= vals[i]
                c += caps[i]
            idx += 1
        lower = events[idx][0] if idx < n_ev else 0.0
        g_low = s - m * lower + c
        # final segment crosses unconditionally (rounding guard; mirrors
        # rust/src/oga/projection.rs)
        if g_low >= capacity or idx >= n_ev:
            tau = (s + c - capacity) / m if m > 0.0 else lower
            tau = min(max(tau, lower), upper)
            break
    return [min(max(z - tau, 0.0), a) for z, a in zip(vals, caps)]


# --------------------------------------------------------------- dense --

def dense_step(p, y, x, eta):
    L, R, K = p["L"], p["R"], p["K"]
    # fused ascent (arrived ports only; linear utilities: f' = alpha)
    for l in range(L):
        xl = x[l]
        if xl == 0.0:
            continue
        quota = [0.0] * K
        for r in p["ports_to_instances"][l]:
            base = (l * R + r) * K
            for k in range(K):
                quota[k] += y[base + k]
        kstar = max(range(K), key=lambda k: p["beta"][k] * quota[k])
        for r in p["ports_to_instances"][l]:
            base = (l * R + r) * K
            for k in range(K):
                pen = p["beta"][k] if k == kstar else 0.0
                y[base + k] += eta * xl * (p["alpha"][r][k] - pen)
    # full dense projection: off-edge re-zeroing + all R*K channels
    for r in range(R):
        for l in range(L):
            if not p["has_edge"][l][r]:
                base = (l * R + r) * K
                for k in range(K):
                    y[base + k] = 0.0
        ports = p["instances_to_ports"][r]
        if not ports:
            continue
        for k in range(K):
            vals = [y[(l * R + r) * K + k] for l in ports]
            caps = [p["demand"][l][k] for l in ports]
            out = project_channel(vals, caps, p["capacity"][r][k])
            for i, l in enumerate(ports):
                y[(l * R + r) * K + k] = out[i]


# ----------------------------------------------------------------- csr --

def csr_step(p, y, x, eta, dirty, dirty_list):
    L, K = p["L"], p["K"]
    del dirty_list[:]
    for l in range(L):
        xl = x[l]
        if xl == 0.0:
            continue
        lo, hi = p["port_ptr"][l], p["port_ptr"][l + 1]
        quota = [0.0] * K
        for e in range(lo, hi):
            base = e * K
            for k in range(K):
                quota[k] += y[base + k]
        kstar = max(range(K), key=lambda k: p["beta"][k] * quota[k])
        for e in range(lo, hi):
            r = p["edge_instance"][e]
            if not dirty[r]:
                dirty[r] = True
                dirty_list.append(r)
            base = e * K
            for k in range(K):
                pen = p["beta"][k] if k == kstar else 0.0
                y[base + k] += eta * xl * (p["alpha"][r][k] - pen)
    # project only the dirty instances; nothing to re-zero
    for r in dirty_list:
        edges = p["instance_edges"][r]
        for k in range(K):
            vals = [y[e * K + k] for e in edges]
            caps = [p["demand"][p["edge_port"][e]][k] for e in edges]
            out = project_channel(vals, caps, p["capacity"][r][k])
            for i, e in enumerate(edges):
                y[e * K + k] = out[i]
    for r in dirty_list:
        dirty[r] = False


def bench(fn, warmup, iters):
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return sum(samples) / len(samples), min(samples)


def main():
    rows = []
    for name, L, R, K, density, warm, iters in [
        ("small 4x16x4", 4, 16, 4, 3.0, 3, 30),
        ("default 10x128x6", 10, 128, 6, 3.0, 3, 20),
        ("large 100x1024x6", 100, 1024, 6, 3.0, 2, 8),
    ]:
        p = make_problem(L, R, K, density, seed=2023)
        rng = random.Random(5)
        x = [1.0 if rng.random() < 0.7 else 0.0 for _ in range(L)]
        eta = 0.5

        y_dense = [0.0] * (L * R * K)
        mean_d, min_d = bench(lambda: dense_step(p, y_dense, x, eta), warm, iters)

        y_csr = [0.0] * (p["E"] * K)
        dirty = [False] * R
        dirty_list = []
        mean_c, min_c = bench(
            lambda: csr_step(p, y_csr, x, eta, dirty, dirty_list), warm, iters
        )

        rows.append(dict(name=name, E=p["E"], dense_coords=L * R * K,
                         csr_coords=p["E"] * K,
                         dense_ms=mean_d * 1e3, csr_ms=mean_c * 1e3,
                         dense_ms_min=min_d * 1e3, csr_ms_min=min_c * 1e3,
                         speedup=mean_d / mean_c))
        print(f"{name:<20} dense {mean_d*1e3:9.3f} ms   csr {mean_c*1e3:9.3f} ms"
              f"   speedup {mean_d/mean_c:6.2f}x   (|E|K={p['E']*K}"
              f" vs LRK={L*R*K})")
    with open("perf_proxy.json", "w") as f:
        json.dump(rows, f, indent=2)
    print("wrote perf_proxy.json")


if __name__ == "__main__":
    main()
