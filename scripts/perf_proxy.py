#!/usr/bin/env python3
"""Structural perf proxy for the Rust hot path (EXPERIMENTS.md §Perf, §Perf-2).

The offline image this repo grows in ships no Rust toolchain, so the
`benches/hot_path.rs` numbers cannot be regenerated here.  This script
mirrors the per-slot implementations *structurally 1:1* (same loops,
same operation counts, same channel projector) in pure Python:

Layout section (PR 1, kept as the cross-PR baseline):
  * dense — the seed's [L, R, K] layout: fused ascent over arrived
    ports, then a full projection that re-zeroes every off-edge
    coordinate of every instance (O(L*R*K)) and projects all R*K
    channels;
  * csr   — the edge-major [E, K] layout with dirty-instance tracking.

Pipeline section (PR 2, this PR's before/after pair): the *full leader
slot* — decide (OGA step) + ledger commit + reward + release — under
sparse Bernoulli(0.1) arrivals:
  * pr1 — PR 1's engine: per-coordinate utility-kind dispatch in the
    ascent/reward inner loops, full-sweep commit (scatter over all
    |E|*K coordinates plus an R*K clamp pass), release as an R*K
    capacity copy;
  * pr2 — this PR: kind-batched runs (one dispatch per same-kind run,
    tight inner loops), incremental commit over only the dirty
    instances' rows, lazy release (flag flip).

Because both sides pay identical interpreter overhead per primitive
operation, each pr1/pr2 *ratio* approximates the Rust ratio of the same
loops (it cannot see cache effects or vectorization, both of which
favor the batched/sparse side, so it is a conservative lower bound).
Regenerate the real numbers with `cargo bench --bench hot_path`
-> BENCH_hot_path.json once a toolchain is available.
"""

import json
import math
import os
import random
import struct
import tempfile
import time
import zlib

KINDS = ("linear", "log", "reciprocal", "poly")


def make_problem(L, R, K, density, seed):
    rng = random.Random(seed)
    ports_to_instances = [[] for _ in range(L)]
    instances_to_ports = [[] for _ in range(R)]
    p = min(1.0, density / L)
    for r in range(R):
        any_edge = False
        for l in range(L):
            if rng.random() < p:
                ports_to_instances[l].append(r)
                instances_to_ports[r].append(l)
                any_edge = True
        if not any_edge:
            l = rng.randrange(L)
            ports_to_instances[l].append(r)
            instances_to_ports[r].append(l)
    for l in range(L):
        if not ports_to_instances[l]:
            r = rng.randrange(R)
            ports_to_instances[l].append(r)
            instances_to_ports[r].append(l)
            instances_to_ports[r].sort()
    # edge-major CSR index (port-major ids)
    port_ptr = [0]
    edge_instance = []
    edge_port = []
    for l in range(L):
        for r in sorted(ports_to_instances[l]):
            edge_instance.append(r)
            edge_port.append(l)
        port_ptr.append(len(edge_instance))
    instance_edges = [[] for _ in range(R)]
    for e, r in enumerate(edge_instance):
        instance_edges[r].append(e)
    has_edge = [[False] * R for _ in range(L)]
    for l in range(L):
        for r in ports_to_instances[l]:
            has_edge[l][r] = True
    demand = [[rng.uniform(0.5, 2.0) for _ in range(K)] for _ in range(L)]
    capacity = [[rng.uniform(2.0, 6.0) for _ in range(K)] for _ in range(R)]
    alpha = [[rng.uniform(1.0, 1.5) for _ in range(K)] for _ in range(R)]
    beta = [rng.uniform(0.3, 0.5) for _ in range(K)]
    kind = [[rng.randrange(4) for _ in range(K)] for _ in range(R)]
    E = len(edge_port)
    # flattened per-coordinate tables + same-kind runs per port
    # (mirrors model::KindIndex)
    kind_flat = [0] * (E * K)
    alpha_flat = [0.0] * (E * K)
    for e in range(E):
        r = edge_instance[e]
        for k in range(K):
            kind_flat[e * K + k] = kind[r][k]
            alpha_flat[e * K + k] = alpha[r][k]
    port_runs = [[] for _ in range(L)]
    for l in range(L):
        lo = port_ptr[l] * K
        hi = port_ptr[l + 1] * K
        c = lo
        while c < hi:
            kk = kind_flat[c]
            start = c
            while c < hi and kind_flat[c] == kk:
                c += 1
            port_runs[l].append((start, c, kk))
    return dict(L=L, R=R, K=K, ports_to_instances=ports_to_instances,
                instances_to_ports=instances_to_ports, port_ptr=port_ptr,
                edge_instance=edge_instance, edge_port=edge_port,
                instance_edges=instance_edges, has_edge=has_edge,
                demand=demand, capacity=capacity, alpha=alpha, beta=beta,
                kind=kind, kind_flat=kind_flat, alpha_flat=alpha_flat,
                port_runs=port_runs, E=E)


def project_instance_csr(p, r, y):
    """Project all K channels of instance r in place — mirrors
    rust/src/oga/projection.rs::project_instance: an allocation-free
    clipped-sum fast path per channel, with the event sweep only when
    the capacity actually binds (Rust reuses per-thread scratch; the
    comprehension-per-channel the proxy used before charged the sparse
    side a Python-only allocation cost the Rust code never pays)."""
    K = p["K"]
    edges = p["instance_edges"][r]
    demand = p["demand"]
    edge_port = p["edge_port"]
    for k in range(K):
        cap_rk = p["capacity"][r][k]
        used = 0.0
        for e in edges:
            z = y[e * K + k]
            a = demand[edge_port[e]][k]
            if z < 0.0:
                z = 0.0
            elif z > a:
                z = a
            used += z
        if used <= cap_rk:
            for e in edges:
                c = e * K + k
                z = y[c]
                a = demand[edge_port[e]][k]
                if z < 0.0:
                    z = 0.0
                elif z > a:
                    z = a
                y[c] = z
            continue
        # capacity binds: gather and run the event sweep
        vals = [y[e * K + k] for e in edges]
        caps = [demand[edge_port[e]][k] for e in edges]
        out = project_channel(vals, caps, cap_rk)
        for i, e in enumerate(edges):
            y[e * K + k] = out[i]


def project_channel(vals, caps, capacity):
    """Shared O(n log n) event-sweep channel projector (both layouts)."""
    used = sum(min(max(z, 0.0), a) for z, a in zip(vals, caps))
    if used <= capacity:
        return [min(max(z, 0.0), a) for z, a in zip(vals, caps)]
    events = []
    for i, (z, a) in enumerate(zip(vals, caps)):
        if z > 0.0:
            events.append((z, 0, i))
        if z - a > 0.0:
            events.append((z - a, 1, i))
    events.sort(key=lambda t: -t[0])
    m = s = c = 0.0
    n_ev = len(events)
    idx = 0
    tau = 0.0
    while idx < n_ev:
        upper = events[idx][0]
        while idx < n_ev and events[idx][0] == upper:
            _, kind, i = events[idx]
            if kind == 0:
                m += 1.0
                s += vals[i]
            else:
                m -= 1.0
                s -= vals[i]
                c += caps[i]
            idx += 1
        lower = events[idx][0] if idx < n_ev else 0.0
        g_low = s - m * lower + c
        # final segment crosses unconditionally (rounding guard; mirrors
        # rust/src/oga/projection.rs)
        if g_low >= capacity or idx >= n_ev:
            tau = (s + c - capacity) / m if m > 0.0 else lower
            tau = min(max(tau, lower), upper)
            break
    return [min(max(z - tau, 0.0), a) for z, a in zip(vals, caps)]


# -------------------------------------------------- utility calculus --

def grad_scalar(kind, y, a):
    """Per-coordinate f'(y) with the if/elif chain the PR 1 inner loops
    paid per coordinate (mirrors the hoisted UtilityKind::grad match)."""
    if y < 0.0:
        y = 0.0
    if kind == 0:
        return a
    elif kind == 1:
        return a / (y + 1.0)
    elif kind == 2:
        d = y + a
        return 1.0 / (d * d)
    else:
        return a / (2.0 * math.sqrt(y + 1.0))


def value_scalar(kind, y, a):
    if y < 0.0:
        y = 0.0
    if kind == 0:
        return a * y
    elif kind == 1:
        return a * math.log(y + 1.0)
    elif kind == 2:
        return 1.0 / a - 1.0 / (y + a)
    else:
        return a * math.sqrt(y + 1.0) - a


# --------------------------------------------------------------- dense --

def dense_step(p, y, x, eta):
    L, R, K = p["L"], p["R"], p["K"]
    # fused ascent (arrived ports only; linear utilities: f' = alpha)
    for l in range(L):
        xl = x[l]
        if xl == 0.0:
            continue
        quota = [0.0] * K
        for r in p["ports_to_instances"][l]:
            base = (l * R + r) * K
            for k in range(K):
                quota[k] += y[base + k]
        kstar = max(range(K), key=lambda k: p["beta"][k] * quota[k])
        for r in p["ports_to_instances"][l]:
            base = (l * R + r) * K
            for k in range(K):
                pen = p["beta"][k] if k == kstar else 0.0
                y[base + k] += eta * xl * (p["alpha"][r][k] - pen)
    # full dense projection: off-edge re-zeroing + all R*K channels
    # (same allocation-free fast path as the CSR side; only the layout
    # and the per-slot work differ)
    demand = p["demand"]
    for r in range(R):
        for l in range(L):
            if not p["has_edge"][l][r]:
                base = (l * R + r) * K
                for k in range(K):
                    y[base + k] = 0.0
        ports = p["instances_to_ports"][r]
        if not ports:
            continue
        for k in range(K):
            cap_rk = p["capacity"][r][k]
            used = 0.0
            for l in ports:
                z = y[(l * R + r) * K + k]
                a = demand[l][k]
                if z < 0.0:
                    z = 0.0
                elif z > a:
                    z = a
                used += z
            if used <= cap_rk:
                for l in ports:
                    c = (l * R + r) * K + k
                    z = y[c]
                    a = demand[l][k]
                    if z < 0.0:
                        z = 0.0
                    elif z > a:
                        z = a
                    y[c] = z
                continue
            vals = [y[(l * R + r) * K + k] for l in ports]
            caps = [demand[l][k] for l in ports]
            out = project_channel(vals, caps, cap_rk)
            for i, l in enumerate(ports):
                y[(l * R + r) * K + k] = out[i]


# ----------------------------------------------------------------- csr --

def csr_step(p, y, x, eta, dirty, dirty_list, batched):
    """One OGA slot on the edge-major layout.  batched=False mirrors the
    PR 1 inner loops (per-coordinate kind dispatch); batched=True mirrors
    §Perf-2 (one dispatch per same-kind run + penalty-lane pass)."""
    L, K = p["L"], p["K"]
    del dirty_list[:]
    for l in range(L):
        xl = x[l]
        if xl == 0.0:
            continue
        lo, hi = p["port_ptr"][l], p["port_ptr"][l + 1]
        quota = [0.0] * K
        for e in range(lo, hi):
            base = e * K
            for k in range(K):
                quota[k] += y[base + k]
        kstar = max(range(K), key=lambda k: p["beta"][k] * quota[k])
        if batched:
            scale = eta * xl
            for start, stop, kk in p["port_runs"][l]:
                af = p["alpha_flat"]
                if kk == 0:
                    for c in range(start, stop):
                        y[c] += scale * af[c]
                elif kk == 1:
                    for c in range(start, stop):
                        yv = y[c] if y[c] > 0.0 else 0.0
                        y[c] += scale * (af[c] / (yv + 1.0))
                elif kk == 2:
                    for c in range(start, stop):
                        yv = y[c] if y[c] > 0.0 else 0.0
                        d = yv + af[c]
                        y[c] += scale / (d * d)
                else:
                    for c in range(start, stop):
                        yv = y[c] if y[c] > 0.0 else 0.0
                        y[c] += scale * af[c] / (2.0 * math.sqrt(yv + 1.0))
            pen = scale * p["beta"][kstar]
            for e in range(lo, hi):
                r = p["edge_instance"][e]
                if not dirty[r]:
                    dirty[r] = True
                    dirty_list.append(r)
                y[e * K + kstar] -= pen
        else:
            for e in range(lo, hi):
                r = p["edge_instance"][e]
                if not dirty[r]:
                    dirty[r] = True
                    dirty_list.append(r)
                base = e * K
                for k in range(K):
                    pen = p["beta"][k] if k == kstar else 0.0
                    fp = grad_scalar(p["kind"][r][k], y[base + k], p["alpha"][r][k])
                    y[base + k] += eta * xl * (fp - pen)
    # project only the dirty instances; nothing to re-zero
    for r in dirty_list:
        project_instance_csr(p, r, y)
    for r in dirty_list:
        dirty[r] = False


# ------------------------------------------------------------- ledgers --

def commit_full(p, y, usage):
    """PR 1 ClusterState::commit — zero usage, scatter all |E|*K, then
    an R*K clamp/accumulate pass."""
    R, K = p["R"], p["K"]
    for i in range(R * K):
        usage[i] = 0.0
    for e in range(p["E"]):
        rbase = p["edge_instance"][e] * K
        base = e * K
        for k in range(K):
            usage[rbase + k] += y[base + k]
    committed = 0.0
    for r in range(R):
        for k in range(K):
            used = usage[r * K + k]
            cap = p["capacity"][r][k]
            if used > cap * (1.0 + 1e-5) + 1e-6 and used > 0.0:
                committed += cap
                usage[r * K + k] = cap
            else:
                committed += used
    return committed


def release_full(p, remaining):
    """PR 1 release — full R*K capacity copy."""
    R, K = p["R"], p["K"]
    for r in range(R):
        for k in range(K):
            remaining[r * K + k] = p["capacity"][r][k]


def commit_dirty(p, y, usage, totals, instances):
    """§Perf-2 ClusterState::commit_instances — re-derive only the dirty
    rows, maintain the running total by deltas."""
    K = p["K"]
    for r in instances:
        base = r * K
        old = 0.0
        for k in range(K):
            old += usage[base + k]
        row = [0.0] * K
        for e in p["instance_edges"][r]:
            eb = e * K
            for k in range(K):
                row[k] += y[eb + k]
        new = 0.0
        for k in range(K):
            used = row[k]
            cap = p["capacity"][r][k]
            if used > cap * (1.0 + 1e-5) + 1e-6 and used > 0.0:
                used = cap
            usage[base + k] = used
            new += used
        totals[0] += new - old
    return totals[0]


# -------------------------------------------------------------- reward --

def reward_scalar(p, x, y):
    """PR 1 slot reward — per-coordinate kind dispatch."""
    L, K = p["L"], p["K"]
    q = 0.0
    for l in range(L):
        xl = x[l]
        if xl == 0.0:
            continue
        lo, hi = p["port_ptr"][l], p["port_ptr"][l + 1]
        gain = 0.0
        quota = [0.0] * K
        for e in range(lo, hi):
            r = p["edge_instance"][e]
            base = e * K
            for k in range(K):
                v = y[base + k]
                gain += value_scalar(p["kind"][r][k], v, p["alpha"][r][k])
                quota[k] += v
        pen = max(p["beta"][k] * quota[k] for k in range(K))
        q += xl * (gain - max(pen, 0.0))
    return q


def reward_batched(p, x, y):
    """§Perf-2 slot_reward_kinds — one dispatch per same-kind run."""
    L, K = p["L"], p["K"]
    af = p["alpha_flat"]
    q = 0.0
    for l in range(L):
        xl = x[l]
        if xl == 0.0:
            continue
        gain = 0.0
        for start, stop, kk in p["port_runs"][l]:
            if kk == 0:
                for c in range(start, stop):
                    yv = y[c] if y[c] > 0.0 else 0.0
                    gain += af[c] * yv
            elif kk == 1:
                for c in range(start, stop):
                    yv = y[c] if y[c] > 0.0 else 0.0
                    gain += af[c] * math.log(yv + 1.0)
            elif kk == 2:
                for c in range(start, stop):
                    yv = y[c] if y[c] > 0.0 else 0.0
                    gain += 1.0 / af[c] - 1.0 / (yv + af[c])
            else:
                for c in range(start, stop):
                    yv = y[c] if y[c] > 0.0 else 0.0
                    gain += af[c] * math.sqrt(yv + 1.0) - af[c]
        lo, hi = p["port_ptr"][l], p["port_ptr"][l + 1]
        quota = [0.0] * K
        for e in range(lo, hi):
            base = e * K
            for k in range(K):
                quota[k] += y[base + k]
        pen = max(p["beta"][k] * quota[k] for k in range(K))
        q += xl * (gain - max(pen, 0.0))
    return q


def bench(fn, warmup, iters):
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return sum(samples) / len(samples), min(samples)


def layout_section(rows):
    """PR 1's dense vs CSR step comparison (kept for the perf record)."""
    for name, L, R, K, density, warm, iters in [
        ("small 4x16x4", 4, 16, 4, 3.0, 3, 30),
        ("default 10x128x6", 10, 128, 6, 3.0, 3, 20),
        ("large 100x1024x6", 100, 1024, 6, 3.0, 2, 8),
    ]:
        p = make_problem(L, R, K, density, seed=2023)
        rng = random.Random(5)
        x = [1.0 if rng.random() < 0.7 else 0.0 for _ in range(L)]
        eta = 0.5

        y_dense = [0.0] * (L * R * K)
        mean_d, min_d = bench(lambda: dense_step(p, y_dense, x, eta), warm, iters)

        y_csr = [0.0] * (p["E"] * K)
        dirty = [False] * R
        dirty_list = []
        mean_c, min_c = bench(
            lambda: csr_step(p, y_csr, x, eta, dirty, dirty_list, batched=True),
            warm, iters,
        )

        rows.append(dict(name=name, E=p["E"], dense_coords=L * R * K,
                         csr_coords=p["E"] * K,
                         dense_ms=mean_d * 1e3, csr_ms=mean_c * 1e3,
                         dense_ms_min=min_d * 1e3, csr_ms_min=min_c * 1e3,
                         speedup=mean_d / mean_c))
        print(f"{name:<20} dense {mean_d*1e3:9.3f} ms   csr {mean_c*1e3:9.3f} ms"
              f"   speedup {mean_d/mean_c:6.2f}x   (|E|K={p['E']*K}"
              f" vs LRK={L*R*K})")


def oracle_step(p, y, x, grad, eta_scale, dirty, dirty_list, active_ports, sparse):
    """One Eq. 50 oracle-rate OGA slot (the Thm. 1 configuration every
    regret experiment runs).  sparse=False mirrors PR 1: gradient into a
    memset |E|*K buffer, norm and ascent over the whole buffer.
    sparse=True mirrors §Perf-2 (gradient_sparse / grad_norm_ports):
    zero only the previously filled slices, then gradient, norm and
    ascent touch the arrived ports' slices alone."""
    L, K, E = p["L"], p["K"], p["E"]
    del dirty_list[:]
    if sparse:
        for l in active_ports:
            for c in range(p["port_ptr"][l] * K, p["port_ptr"][l + 1] * K):
                grad[c] = 0.0
        del active_ports[:]
    else:
        for c in range(E * K):
            grad[c] = 0.0
    for l in range(L):
        xl = x[l]
        if xl == 0.0:
            continue
        active_ports.append(l)
        lo, hi = p["port_ptr"][l], p["port_ptr"][l + 1]
        quota = [0.0] * K
        for e in range(lo, hi):
            base = e * K
            for k in range(K):
                quota[k] += y[base + k]
        kstar = max(range(K), key=lambda k: p["beta"][k] * quota[k])
        for e in range(lo, hi):
            r = p["edge_instance"][e]
            if not dirty[r]:
                dirty[r] = True
                dirty_list.append(r)
            base = e * K
            for k in range(K):
                pen = p["beta"][k] if k == kstar else 0.0
                fp = grad_scalar(p["kind"][r][k], y[base + k], p["alpha"][r][k])
                grad[base + k] = xl * (fp - pen)
    if sparse:
        norm = 0.0
        for l in active_ports:
            for c in range(p["port_ptr"][l] * K, p["port_ptr"][l + 1] * K):
                g = grad[c]
                norm += g * g
    else:
        norm = 0.0
        for c in range(E * K):
            g = grad[c]
            norm += g * g
    eta = eta_scale / max(math.sqrt(norm), 1e-9)
    if sparse:
        for l in active_ports:
            for c in range(p["port_ptr"][l] * K, p["port_ptr"][l + 1] * K):
                y[c] += eta * grad[c]
    else:
        for c in range(E * K):
            y[c] += eta * grad[c]
    for r in dirty_list:
        project_instance_csr(p, r, y)
    for r in dirty_list:
        dirty[r] = False


def pipeline_section(rows):
    """§Perf-2: the full leader slot (decide incl. publish + commit +
    score + release) under sparse Bernoulli(0.1) arrivals — PR 1 engine
    vs the arrival-sparse pipeline, for both learning-rate schedules.

    PR 1 per-slot |E|-proportional costs removed by this PR: the decide
    publish (`y.copy_from_slice` of the whole tensor), the full-sweep
    commit scatter + R*K clamp pass, the R*K release copy, and — on the
    oracle schedule — the gradient memset, full-buffer norm and
    full-buffer ascent."""
    for name, L, R, K, density, warm, iters in [
        ("default 10x128x6", 10, 128, 6, 3.0, 3, 20),
        ("large 100x1024x6", 100, 1024, 6, 3.0, 2, 15),
    ]:
        p = make_problem(L, R, K, density, seed=2023)
        E = p["E"]
        eta = 0.5

        def run_pipeline(pr2, schedule):
            rng = random.Random(17)
            y = [0.0] * (E * K)
            y_out = [0.0] * (E * K)
            grad = [0.0] * (E * K)
            dirty = [False] * R
            dirty_list = []
            active_ports = []
            usage = [0.0] * (R * K)
            remaining = [0.0] * (R * K)
            totals = [0.0]
            x = [0.0] * L

            def slot():
                for l in range(L):
                    x[l] = 1.0 if rng.random() < 0.1 else 0.0
                if schedule == "decay":
                    csr_step(p, y, x, eta, dirty, dirty_list, batched=pr2)
                else:
                    oracle_step(p, y, x, grad, 2.0, dirty, dirty_list,
                                active_ports, sparse=pr2)
                if pr2:
                    # publish only the dirty columns into the engine buffer
                    for r in dirty_list:
                        for e in p["instance_edges"][r]:
                            b = e * K
                            for k in range(K):
                                y_out[b + k] = y[b + k]
                    commit_dirty(p, y_out, usage, totals, dirty_list)
                    reward_batched(p, x, y_out)
                    # lazy release: flag flip, nothing to do
                else:
                    # PR 1 decide published the whole tensor every slot
                    for c in range(E * K):
                        y_out[c] = y[c]
                    commit_full(p, y_out, usage)
                    reward_scalar(p, x, y_out)
                    release_full(p, remaining)

            # batch slots per timed sample: averages out the Bernoulli
            # arrival variance (zero-arrival slots are near-free on the
            # sparse side — by design — which would make single-slot
            # minima unrepresentative of the typical slot)
            batch = 10

            def sample(slot=slot):
                for _ in range(batch):
                    slot()

            return sample, batch

        for schedule in ("decay", "oracle"):
            f1, batch = run_pipeline(False, schedule)
            mean_1, min_1 = bench(f1, warm, iters)
            f2, _ = run_pipeline(True, schedule)
            mean_2, min_2 = bench(f2, warm, iters)
            mean_1, min_1 = mean_1 / batch, min_1 / batch
            mean_2, min_2 = mean_2 / batch, min_2 / batch
            rows.append(dict(name=name, schedule=schedule,
                             section="pipeline-sparse10",
                             pr1_ms=mean_1 * 1e3, pr2_ms=mean_2 * 1e3,
                             pr1_ms_min=min_1 * 1e3, pr2_ms_min=min_2 * 1e3,
                             speedup=mean_1 / mean_2,
                             speedup_min=min_1 / min_2))
            print(f"slot sparse10 {schedule:<6} {name:<20}"
                  f" pr1 {mean_1*1e3:9.3f} ms   pr2 {mean_2*1e3:9.3f} ms"
                  f"   speedup {mean_1/mean_2:6.2f}x"
                  f" (min {min_1/min_2:.2f}x)")


# ------------------------------------------------- sharded slot model --

# Pool scatter-gather dispatch cost per fan-out, measured order of the
# Rust pool's steady-state submit (mutex round-trip + condvar wakes;
# utils/pool.rs module docs: "single-digit microseconds").
DISPATCH_US = 5.0
# Scatters per sharded slot: ascent, projection, commit, reward.
DISPATCHES_PER_SLOT = 4


def sharded_stage_times(p, warm, iters, rho=0.1):
    """Run the §Perf-2 decay slot split into the §Perf-3 stage structure
    and accumulate per-stage serial time:

      ascent_serial      phase A — per-port quota/k* + dirty discovery
      ascent_parallel    phase B — per-edge ascent + k*-lane penalty
      project_parallel   dirty-instance projection
      publish_serial     dirty-column publish into the engine buffer
      commit_parallel    per-row usage re-derivation (shard ledgers)
      merge_serial       row fold + Σ-delta replay + reward merge
      reward_parallel    per-port reward kernels

    The split mirrors coordinator::sharded exactly: what is charged
    'parallel' is what the Rust slot fans out over the pool, and the
    floats produced equal the unsplit pr2 slot's (same per-coordinate
    ops, same order)."""
    L, R, K = p["L"], p["R"], p["K"]
    E = p["E"]
    af = p["alpha_flat"]
    eta = 0.5
    rng = random.Random(17)
    y = [0.0] * (E * K)
    y_out = [0.0] * (E * K)
    g_usage = [0.0] * (R * K)
    usage = [0.0] * (R * K)
    totals = [0.0]
    dirty = [False] * R
    dirty_list = []
    x = [0.0] * L
    times = {k: 0.0 for k in ("ascent_serial", "ascent_parallel", "project_parallel",
                              "publish_serial", "commit_parallel", "merge_serial",
                              "reward_parallel")}
    slots = 0

    def slot(record):
        nonlocal slots
        for l in range(L):
            x[l] = 1.0 if rng.random() < rho else 0.0
        del dirty_list[:]
        steps = []
        t0 = time.perf_counter()
        # phase A: quotas, k*, dirty discovery (leader thread)
        for l in range(L):
            xl = x[l]
            if xl == 0.0:
                continue
            lo, hi = p["port_ptr"][l], p["port_ptr"][l + 1]
            quota = [0.0] * K
            for e in range(lo, hi):
                base = e * K
                for k in range(K):
                    quota[k] += y[base + k]
            kstar = max(range(K), key=lambda k: p["beta"][k] * quota[k])
            steps.append((l, eta * xl, kstar))
            for e in range(lo, hi):
                r = p["edge_instance"][e]
                if not dirty[r]:
                    dirty[r] = True
                    dirty_list.append(r)
        t1 = time.perf_counter()
        # phase B: per-edge ascent + penalty (sharded in Rust)
        for (l, scale, kstar) in steps:
            lo, hi = p["port_ptr"][l], p["port_ptr"][l + 1]
            for e in range(lo, hi):
                base = e * K
                for k in range(K):
                    c = base + k
                    kk = p["kind_flat"][c]
                    yv = y[c] if y[c] > 0.0 else 0.0
                    if kk == 0:
                        fp = af[c]
                    elif kk == 1:
                        fp = af[c] / (yv + 1.0)
                    elif kk == 2:
                        d = yv + af[c]
                        fp = 1.0 / (d * d)
                    else:
                        fp = af[c] / (2.0 * math.sqrt(yv + 1.0))
                    y[c] += scale * fp
                y[base + kstar] -= scale * p["beta"][kstar]
        t2 = time.perf_counter()
        # dirty projection (sharded in Rust)
        for r in dirty_list:
            project_instance_csr(p, r, y)
        t3 = time.perf_counter()
        # publish dirty columns (leader thread)
        for r in dirty_list:
            for e in p["instance_edges"][r]:
                b = e * K
                for k in range(K):
                    y_out[b + k] = y[b + k]
        t4 = time.perf_counter()
        # per-row commit (shard ledgers in Rust)
        deltas = [0.0] * len(dirty_list)
        for i, r in enumerate(dirty_list):
            base = r * K
            old = 0.0
            for k in range(K):
                old += usage[base + k]
            row = [0.0] * K
            for e in p["instance_edges"][r]:
                eb = e * K
                for k in range(K):
                    row[k] += y_out[eb + k]
            new = 0.0
            for k in range(K):
                used = row[k]
                cap = p["capacity"][r][k]
                if used > cap * (1.0 + 1e-5) + 1e-6 and used > 0.0:
                    used = cap
                usage[base + k] = used
                new += used
            deltas[i] = new - old
        t5 = time.perf_counter()
        # fold: row copies into the global ledger + Σ-delta replay
        for r in dirty_list:
            base = r * K
            for k in range(K):
                g_usage[base + k] = usage[base + k]
        for d in deltas:
            totals[0] += d
        t6 = time.perf_counter()
        # per-port reward kernels (sharded in Rust)
        arrived = [l for l in range(L) if x[l] != 0.0]
        gains = [0.0] * len(arrived)
        pens = [0.0] * len(arrived)
        for i, l in enumerate(arrived):
            gain = 0.0
            for start, stop, kk in p["port_runs"][l]:
                if kk == 0:
                    for c in range(start, stop):
                        yv = y_out[c] if y_out[c] > 0.0 else 0.0
                        gain += af[c] * yv
                elif kk == 1:
                    for c in range(start, stop):
                        yv = y_out[c] if y_out[c] > 0.0 else 0.0
                        gain += af[c] * math.log(yv + 1.0)
                elif kk == 2:
                    for c in range(start, stop):
                        yv = y_out[c] if y_out[c] > 0.0 else 0.0
                        gain += 1.0 / af[c] - 1.0 / (yv + af[c])
                else:
                    for c in range(start, stop):
                        yv = y_out[c] if y_out[c] > 0.0 else 0.0
                        gain += af[c] * math.sqrt(yv + 1.0) - af[c]
            lo, hi = p["port_ptr"][l], p["port_ptr"][l + 1]
            quota = [0.0] * K
            for e in range(lo, hi):
                base = e * K
                for k in range(K):
                    quota[k] += y_out[base + k]
            gains[i] = gain
            pens[i] = max([p["beta"][k] * quota[k] for k in range(K)] + [0.0])
        t7 = time.perf_counter()
        # serial reward merge (ascending port order)
        q = 0.0
        for i, l in enumerate(arrived):
            q += x[l] * (gains[i] - pens[i])
        t8 = time.perf_counter()
        for r in dirty_list:
            dirty[r] = False
        if record:
            times["ascent_serial"] += t1 - t0
            times["ascent_parallel"] += t2 - t1
            times["project_parallel"] += t3 - t2
            times["publish_serial"] += t4 - t3
            times["commit_parallel"] += t5 - t4
            times["merge_serial"] += (t6 - t5) + (t8 - t7)
            times["reward_parallel"] += t7 - t6
            slots += 1
        return q

    for _ in range(warm * 10):
        slot(False)
    for _ in range(iters * 10):
        slot(True)
    return {k: v / slots for k, v in times.items()}


def sharded_section(rows):
    """§Perf-3: model the sharded single-slot latency at S shards from
    the measured stage split — Amdahl over the shardable stages plus the
    pool's scatter dispatch cost:

        t(S) = serial + parallel / S + (S > 1) · 4 · dispatch

    The per-stage times are measured on the same structural mirror as
    the pr2 pipeline rows, so the shard1 row is directly comparable to
    the `leader slot sparse10 decay incr` row; balance loss from the
    LPT partition is not modeled (bounded by max_r |E_r|K / (Σ|E_r|K/S),
    small at density 3)."""
    for name, L, R, K, density, warm, iters in [
        ("default 10x128x6", 10, 128, 6, 3.0, 3, 20),
        ("large 100x1024x6", 100, 1024, 6, 3.0, 2, 15),
    ]:
        p = make_problem(L, R, K, density, seed=2023)
        st = sharded_stage_times(p, warm, iters)
        serial = (st["ascent_serial"] + st["publish_serial"] + st["merge_serial"])
        parallel = (st["ascent_parallel"] + st["project_parallel"]
                    + st["commit_parallel"] + st["reward_parallel"])
        t1 = serial + parallel
        for shards in (1, 2, 4, 8):
            t_s = serial + parallel / shards
            if shards > 1:
                t_s += DISPATCHES_PER_SLOT * DISPATCH_US * 1e-6
            rows.append(dict(name=name, section="sharded-slot-model",
                             shards=shards, modeled_ms=t_s * 1e3,
                             serial_ms=serial * 1e3, parallel_ms=parallel * 1e3,
                             speedup=t1 / t_s))
            print(f"slot sparse10 decay shard{shards} {name:<20}"
                  f" modeled {t_s*1e3:9.3f} ms   speedup {t1/t_s:6.2f}x"
                  f"   (serial {serial*1e3:.3f} ms, parallel {parallel*1e3:.3f} ms)")


def oracle_solve_stage_times(p, warm, iters, horizon=200, rho=0.7):
    """Stage split of one `regret::solve_oracle` iteration (the Eq. 50
    offline benchmark, §Perf-4).  Mirrors the Rust loop stage for
    stage; the split matches what the sharded solve fans out:

      phase_a_serial    per-port quota/k* reductions (caller thread)
      grad_parallel     per-edge gradient fill + k*-lane penalty
      norm_serial       ||grad|| over the active slices (serial replay)
      ascent_parallel   y += eta * grad on active slices
      project_parallel  active-instance projection
      objective_serial  weighted slot reward (serial replay)
    """
    L, K = p["L"], p["K"]
    rng = random.Random(31)
    counts = [0.0] * L
    for _ in range(horizon):
        for l in range(L):
            if rng.random() < rho:
                counts[l] += 1.0
    active_ports = [l for l in range(L) if counts[l] != 0.0]
    active_instances = sorted({r for l in active_ports
                               for r in p["ports_to_instances"][l]})
    E = p["E"]
    y = [0.0] * (E * K)
    grad = [0.0] * (E * K)
    af = p["alpha_flat"]
    times = {k: 0.0 for k in ("phase_a_serial", "grad_parallel", "norm_serial",
                              "ascent_parallel", "project_parallel",
                              "objective_serial")}
    iters_done = 0

    def iteration(record, eta):
        nonlocal iters_done
        t0 = time.perf_counter()
        steps = []
        for l in active_ports:
            lo, hi = p["port_ptr"][l], p["port_ptr"][l + 1]
            quota = [0.0] * K
            for e in range(lo, hi):
                base = e * K
                for k in range(K):
                    quota[k] += y[base + k]
            kstar = max(range(K), key=lambda k: p["beta"][k] * quota[k])
            steps.append((l, counts[l], kstar))
        t1 = time.perf_counter()
        for (l, xl, kstar) in steps:
            lo, hi = p["port_ptr"][l], p["port_ptr"][l + 1]
            pen = xl * p["beta"][kstar]
            for e in range(lo, hi):
                base = e * K
                for k in range(K):
                    c = base + k
                    kk = p["kind_flat"][c]
                    yv = y[c] if y[c] > 0.0 else 0.0
                    if kk == 0:
                        fp = af[c]
                    elif kk == 1:
                        fp = af[c] / (yv + 1.0)
                    elif kk == 2:
                        d = yv + af[c]
                        fp = 1.0 / (d * d)
                    else:
                        fp = af[c] / (2.0 * math.sqrt(yv + 1.0))
                    grad[c] = xl * fp
                grad[base + kstar] -= pen
        t2 = time.perf_counter()
        norm = 0.0
        for l in active_ports:
            for c in range(p["port_ptr"][l] * K, p["port_ptr"][l + 1] * K):
                g = grad[c]
                norm += g * g
        t3 = time.perf_counter()
        step = eta / max(math.sqrt(norm), 1e-12)
        for l in active_ports:
            for c in range(p["port_ptr"][l] * K, p["port_ptr"][l + 1] * K):
                y[c] += step * grad[c]
        t4 = time.perf_counter()
        for r in active_instances:
            project_instance_csr(p, r, y)
        t5 = time.perf_counter()
        reward_batched(p, counts, y)
        t6 = time.perf_counter()
        if record:
            times["phase_a_serial"] += t1 - t0
            times["grad_parallel"] += t2 - t1
            times["norm_serial"] += t3 - t2
            times["ascent_parallel"] += t4 - t3
            times["project_parallel"] += t5 - t4
            times["objective_serial"] += t6 - t5
            iters_done += 1

    for _ in range(warm):
        iteration(False, 1.0)
    for _ in range(iters):
        iteration(True, 1.0)
    return {k: v / iters_done for k, v in times.items()}


# Scatters per sharded solve_oracle iteration: gradient fill, ascent,
# projection (PR 4); §Perf-5 adds phase A and the objective -> 5.
ORACLE_DISPATCHES_PER_ITER = 3
ORACLE_DISPATCHES_PER_ITER_P5 = 5


def perf4_section(rows):
    """§Perf-4: the two-level execution budget.

    (a) sharded-oracle rows: model one solve_oracle iteration at S
        shards from the measured stage split —
        t(S) = serial + parallel/S + (S > 1) * 3 * dispatch —
        the same Amdahl shape as the §Perf-3 slot model, now applied to
        the Eq. 50 offline benchmark (phase A / ||grad|| / objective
        replay serially; gradient fill, ascent, projection fan out).

    (b) budgeted-lineup rows: extend the model to the runs x shards
        split.  A lineup of N independent runs on a W-worker budget
        finishes in ceil(N / runs) waves of the per-run sharded slot
        time, so per slot
            t_lineup(runs, shards) = ceil(N / runs) * t_slot(shards)
        with t_slot from the §Perf-3 decay split.  The serial floor is
        N * t_slot(1).  Balance loss and lane skew are not modeled."""
    for name, L, R, K, density, warm, iters in [
        ("default 10x128x6", 10, 128, 6, 3.0, 3, 20),
        ("large 100x1024x6", 100, 1024, 6, 3.0, 2, 10),
    ]:
        p = make_problem(L, R, K, density, seed=2023)
        st = oracle_solve_stage_times(p, warm, iters)
        serial = st["phase_a_serial"] + st["norm_serial"] + st["objective_serial"]
        parallel = (st["grad_parallel"] + st["ascent_parallel"]
                    + st["project_parallel"])
        t1 = serial + parallel
        for shards in (1, 2, 4, 8):
            t_s = serial + parallel / shards
            if shards > 1:
                t_s += ORACLE_DISPATCHES_PER_ITER * DISPATCH_US * 1e-6
            rows.append(dict(name=name, section="sharded-oracle-model",
                             shards=shards, modeled_ms=t_s * 1e3,
                             serial_ms=serial * 1e3, parallel_ms=parallel * 1e3,
                             speedup=t1 / t_s))
            print(f"solve_oracle iter shard{shards} {name:<20}"
                  f" modeled {t_s*1e3:9.3f} ms   speedup {t1/t_s:6.2f}x"
                  f"   (serial {serial*1e3:.3f} ms, parallel {parallel*1e3:.3f} ms)")

    # (b) lineup under a split of a pinned W=4 budget (the CI matrix
    # pin), N = 5 paper-lineup policies, decay slot stage split
    n_runs = 5
    for name, L, R, K, density, warm, iters in [
        ("default 10x128x6", 10, 128, 6, 3.0, 3, 20),
    ]:
        p = make_problem(L, R, K, density, seed=2023)
        st = sharded_stage_times(p, warm, iters, rho=0.7)
        serial = st["ascent_serial"] + st["publish_serial"] + st["merge_serial"]
        parallel = (st["ascent_parallel"] + st["project_parallel"]
                    + st["commit_parallel"] + st["reward_parallel"])

        def t_slot(shards):
            t = serial + parallel / shards
            if shards > 1:
                t += DISPATCHES_PER_SLOT * DISPATCH_US * 1e-6
            return t

        t_serial_lineup = n_runs * t_slot(1)
        for label, runs, shards in [("serial", 1, 1), ("1x4", 1, 4),
                                    ("2x2", 2, 2), ("4x1", 4, 1)]:
            waves = -(-n_runs // runs)  # ceil
            t_l = waves * t_slot(shards)
            rows.append(dict(name=name, section="lineup-budget-model",
                             split=label, runs=runs, shards=shards,
                             modeled_ms=t_l * 1e3,
                             speedup=t_serial_lineup / t_l))
            print(f"lineup {n_runs}pol budget {label:<6} {name:<20}"
                  f" modeled {t_l*1e3:9.3f} ms/slot-wave"
                  f"   speedup {t_serial_lineup/t_l:6.2f}x")


# ------------------------------------------------------ §Perf-5 models --

# Relative per-element op costs for the kernel lane model (order-of-
# magnitude x86-64 latencies, in add/mul units): division and sqrt are
# pipelined ~4x an add, ln is a scalar libm call.  The lane model
# divides the vectorizable portion by the lane width; ln has no
# portable-SIMD form (oga::kernels evaluates it per lane through the
# same f64::ln), so its cost stays lane-serial.  These rows are MODELED
# — the real numbers come from `cargo bench --bench hot_path` with and
# without `--features simd`.
OP_ADD, OP_MUL, OP_DIV, OP_SQRT, OP_LN = 1.0, 1.0, 4.0, 4.0, 12.0
F64_LANES = 4
F32_LANES = 8

# (vectorizable, lane_serial) op units per element of value_sum / f64
KERNEL_OPS = {
    # clamp(max) + the Eq. 51 value + the accumulator add
    "linear": (OP_ADD + OP_MUL + OP_ADD, 0.0),
    "log": (OP_ADD + OP_ADD + OP_MUL + OP_ADD, OP_LN),
    "reciprocal": (OP_ADD + OP_DIV + OP_ADD + OP_DIV + OP_ADD + OP_ADD, 0.0),
    "poly": (OP_ADD + OP_ADD + OP_SQRT + OP_MUL + OP_ADD + OP_ADD, 0.0),
}
# grad_into per element (no reduction; log's f' = a/(y+1) needs no ln)
GRAD_OPS = {
    "linear": (OP_ADD + OP_MUL, 0.0),
    "log": (OP_ADD + OP_ADD + OP_DIV + OP_MUL, 0.0),
    "reciprocal": (OP_ADD + OP_ADD + OP_MUL + OP_DIV + OP_MUL, 0.0),
    "poly": (OP_ADD + OP_ADD + OP_SQRT + OP_MUL + OP_DIV + OP_MUL, 0.0),
}


def kernel_lane_speedup(ops, lanes):
    vec, serial = ops
    return (vec + serial) / (vec / lanes + serial)


def value_sum_mirror(p_runs, y, af, kind_code):
    """Structural mirror of one value_sum pass (per-kind, n elements) —
    times the *scalar* kernel; the lane rows are modeled from it."""
    acc = 0.0
    if kind_code == 0:
        for c in p_runs:
            yv = y[c] if y[c] > 0.0 else 0.0
            acc += af[c] * yv
    elif kind_code == 1:
        for c in p_runs:
            yv = y[c] if y[c] > 0.0 else 0.0
            acc += af[c] * math.log(yv + 1.0)
    elif kind_code == 2:
        for c in p_runs:
            yv = y[c] if y[c] > 0.0 else 0.0
            acc += 1.0 / af[c] - 1.0 / (yv + af[c])
    else:
        for c in p_runs:
            yv = y[c] if y[c] > 0.0 else 0.0
            acc += af[c] * math.sqrt(yv + 1.0) - af[c]
    return acc


def grad_into_mirror(idx, y, af, out, kind_code, scale=0.75):
    """Structural mirror of one grad_into pass (per-kind, n elements) —
    note log's f' = a/(y+1) has no ln, so its scalar cost differs from
    the value_sum mirror's; the rows are timed separately."""
    if kind_code == 0:
        for c in idx:
            out[c] = scale * af[c]
    elif kind_code == 1:
        for c in idx:
            yv = y[c] if y[c] > 0.0 else 0.0
            out[c] = scale * (af[c] / (yv + 1.0))
    elif kind_code == 2:
        for c in idx:
            yv = y[c] if y[c] > 0.0 else 0.0
            d = yv + af[c]
            out[c] = scale / (d * d)
    else:
        for c in idx:
            yv = y[c] if y[c] > 0.0 else 0.0
            out[c] = scale * af[c] / (2.0 * math.sqrt(yv + 1.0))


def perf5_kernel_section(rows):
    """§Perf-5 (b): scalar-vs-lane kernel rows.  The scalar side is
    timed on the structural mirrors (n = 4096, matching the bench's
    `kernel * n=4096` rows; value_sum and grad_into each on their own
    mirror); the lane side divides the vectorizable op share by the
    lane width (ln stays lane-serial) — the op split is the documented
    KERNEL_OPS/GRAD_OPS model, not a measurement."""
    n = 4096
    rng = random.Random(29)
    y = [rng.uniform(0.0, 3.0) for _ in range(n)]
    af = [rng.uniform(0.5, 2.0) for _ in range(n)]
    out = [0.0] * n
    idx = list(range(n))
    speedups_f64 = []
    for code, name in enumerate(KINDS):
        timed = {
            "value_sum": bench(lambda: value_sum_mirror(idx, y, af, code), 5, 40),
            "grad_into": bench(lambda: grad_into_mirror(idx, y, af, out, code), 5, 40),
        }
        for fn_name, ops in (("value_sum", KERNEL_OPS[name]),
                             ("grad_into", GRAD_OPS[name])):
            mean_s, min_s = timed[fn_name]
            s64 = kernel_lane_speedup(ops, F64_LANES)
            s32 = kernel_lane_speedup(ops, F32_LANES)
            if fn_name == "value_sum":
                speedups_f64.append(s64)
            rows.append(dict(section="kernel-lane-model", kernel=fn_name,
                             kind=name, n=n,
                             scalar_ms=mean_s * 1e3, scalar_ms_min=min_s * 1e3,
                             lane_speedup_f64=s64, lane_speedup_f32=s32,
                             modeled_lane_ms=mean_s * 1e3 / s64))
            print(f"kernel {fn_name:<10} {name:<10} n={n}"
                  f" scalar {mean_s*1e3:8.3f} ms   lane f64 {s64:5.2f}x"
                  f"   lane f32 {s32:5.2f}x")
    mean_speedup = sum(speedups_f64) / len(speedups_f64)
    rows.append(dict(section="kernel-lane-model", kernel="value_sum",
                     kind="mean", n=n, lane_speedup_f64=mean_speedup))
    print(f"kernel value_sum mean lane speedup (f64): {mean_speedup:5.2f}x"
          " (log is the lane-serial-ln outlier; every grad row is full-width)")


def perf5_objective_section(rows):
    """§Perf-5 (a): the sharded oracle objective.  Same measured stage
    split as the §Perf-4 model, re-partitioned: phase A and the
    objective move from the serial to the parallel side (the objective
    through the per-port reward kernels + ascending serial merge, phase
    A through the per-port quota/k* fan-out), leaving only the ||grad||
    replay serial —

        PR 4:  t4(S) = (phase_a + norm + objective) + (grad+ascent+proj)/S + 3d
        PR 5:  t5(S) = norm + (phase_a + grad + ascent + proj + objective)/S + 5d

    The `vs_pr4` column is the per-iteration win of this PR at equal
    shard count; acceptance asks >= 1.3x at S = 8 on the large scale."""
    for name, L, R, K, density, warm, iters in [
        ("default 10x128x6", 10, 128, 6, 3.0, 3, 20),
        ("large 100x1024x6", 100, 1024, 6, 3.0, 2, 10),
    ]:
        p = make_problem(L, R, K, density, seed=2023)
        st = oracle_solve_stage_times(p, warm, iters)
        serial4 = st["phase_a_serial"] + st["norm_serial"] + st["objective_serial"]
        par4 = st["grad_parallel"] + st["ascent_parallel"] + st["project_parallel"]
        serial5 = st["norm_serial"]
        par5 = (st["phase_a_serial"] + st["grad_parallel"] + st["ascent_parallel"]
                + st["project_parallel"] + st["objective_serial"])
        t1 = serial5 + par5
        for shards in (1, 2, 4, 8):
            t4 = serial4 + par4 / shards
            t5 = serial5 + par5 / shards
            if shards > 1:
                t4 += ORACLE_DISPATCHES_PER_ITER * DISPATCH_US * 1e-6
                t5 += ORACLE_DISPATCHES_PER_ITER_P5 * DISPATCH_US * 1e-6
            rows.append(dict(name=name, section="sharded-objective-model",
                             shards=shards, modeled_ms=t5 * 1e3,
                             serial_ms=serial5 * 1e3, parallel_ms=par5 * 1e3,
                             speedup=t1 / t5, vs_pr4=t4 / t5))
            print(f"solve_oracle iter(obj-sharded) shard{shards} {name:<20}"
                  f" modeled {t5*1e3:9.3f} ms   speedup {t1/t5:6.2f}x"
                  f"   vs PR4 {t4/t5:5.2f}x")

        # the objective evaluation alone (matches the bench's
        # `oracle objective shard{S}` rows): obj/S + one dispatch
        obj = st["objective_serial"]
        for shards in (1, 2, 4, 8):
            t_o = obj / shards + (DISPATCH_US * 1e-6 if shards > 1 else 0.0)
            rows.append(dict(name=name, section="sharded-objective-eval",
                             shards=shards, modeled_ms=t_o * 1e3,
                             speedup=obj / t_o))
            print(f"oracle objective shard{shards} {name:<20}"
                  f" modeled {t_o*1e3:9.3f} ms   speedup {obj/t_o:6.2f}x")


def traffic_section(rows):
    """Sparse-figure regime check: the same pr2 decay slot at the figure
    harnesses' two traffic levels.  The ρ = 0.1 column is what the new
    `ogasched figure sparse` harness exercises for a whole horizon; the
    ratio is the per-slot win of the arrival-sparse pipeline in that
    regime vs the dense fig2 traffic."""
    for name, L, R, K, density, warm, iters in [
        ("default 10x128x6", 10, 128, 6, 3.0, 3, 20),
        ("large 100x1024x6", 100, 1024, 6, 3.0, 2, 10),
    ]:
        per_rho = {}
        for rho in (0.1, 0.7):
            p = make_problem(L, R, K, density, seed=2023)
            st = sharded_stage_times(p, warm, iters, rho=rho)
            per_rho[rho] = sum(st.values())
        rows.append(dict(name=name, section="traffic-sparse-vs-dense",
                         sparse_ms=per_rho[0.1] * 1e3, dense_ms=per_rho[0.7] * 1e3,
                         ratio=per_rho[0.7] / per_rho[0.1]))
        print(f"slot decay {name:<20} rho=0.1 {per_rho[0.1]*1e3:9.3f} ms"
              f"   rho=0.7 {per_rho[0.7]*1e3:9.3f} ms"
              f"   dense/sparse {per_rho[0.7]/per_rho[0.1]:6.2f}x")


# ------------------------------------------------------- §Churn model --

def _churn_csr(L, R, edges):
    """Rebuild the port-major CSR index from a sorted edge list
    (mirrors graph::Bipartite::rebuild_index)."""
    port_ptr = [0]
    edge_instance = []
    i = 0
    for l in range(L):
        while i < len(edges) and edges[i][0] == l:
            edge_instance.append(edges[i][1])
            i += 1
        port_ptr.append(len(edge_instance))
    instance_edges = [[] for _ in range(R)]
    for e, r in enumerate(edge_instance):
        instance_edges[r].append(e)
    return port_ptr, edge_instance, instance_edges


def _churn_kind_index(L, K, port_ptr, edge_instance, kind):
    """Flat per-coordinate tables + same-kind runs (mirrors
    model::KindIndex::build)."""
    kind_flat = []
    for r in edge_instance:
        kind_flat.extend(kind[r])
    port_runs = []
    for l in range(L):
        lo, hi = port_ptr[l] * K, port_ptr[l + 1] * K
        c = lo
        runs = []
        while c < hi:
            kk = kind_flat[c]
            start = c
            while c < hi and kind_flat[c] == kk:
                c += 1
            runs.append((start, c, kk))
        port_runs.append(runs)
    return kind_flat, port_runs


def _churn_lpt(R, K, instance_edges, shards):
    """Greedy LPT over per-instance weights + per-shard edge CSRs
    (mirrors coordinator::ShardPlan::build)."""
    import heapq
    loads = sorted(((len(instance_edges[r]) * K, r) for r in range(R)),
                   reverse=True)
    heap = [(0, s) for s in range(shards)]
    heapq.heapify(heap)
    owner = [0] * R
    for w, r in loads:
        tot, s = heapq.heappop(heap)
        owner[r] = s
        heapq.heappush(heap, (tot + w, s))
    shard_edges = [[] for _ in range(shards)]
    for r in range(R):
        shard_edges[owner[r]].extend(instance_edges[r])
    return owner, shard_edges


def _churn_refresh(owner, instance_edges, shards):
    """Keep owners, recompute per-shard CSRs + loads (mirrors
    coordinator::ShardPlan::refresh)."""
    shard_edges = [[] for _ in range(shards)]
    loads = [0] * shards
    for r, es in enumerate(instance_edges):
        s = owner[r]
        shard_edges[s].extend(es)
        loads[s] += len(es)
    return shard_edges, loads


def churn_section(rows):
    """§Churn: one topology edition pair (instance fails, then recovers)
    — incremental apply + plan refresh vs from-scratch Problem + LPT
    rebuild; structural mirror of benches/hot_path.rs's churn rows."""
    name, L, R, K, density = "large 100x1024x6", 100, 1024, 6, 3.0
    shards = 8
    p = make_problem(L, R, K, density, seed=2023)
    kind = p["kind"]
    e0 = sorted(zip(p["edge_port"], p["edge_instance"]))
    r_fail = 7
    live = [(l, r) for (l, r) in e0 if r != r_fail]
    back = [(l, r) for (l, r) in e0 if r == r_fail]
    owner, _ = _churn_lpt(R, K, p["instance_edges"], shards)

    def incremental():
        # fail: retain + reindex + kinds + refresh
        edges = [(l, r) for (l, r) in e0 if r != r_fail]
        ptr, ei, inst = _churn_csr(L, R, edges)
        _churn_kind_index(L, K, ptr, ei, kind)
        _churn_refresh(owner, inst, shards)
        # recover: merge the restore set back + reindex + refresh
        edges = sorted(edges + back)
        ptr, ei, inst = _churn_csr(L, R, edges)
        _churn_kind_index(L, K, ptr, ei, kind)
        _churn_refresh(owner, inst, shards)

    def rebuild():
        for edges in (live, e0):
            se = sorted(edges)  # Bipartite::from_edges sorts
            ptr, ei, inst = _churn_csr(L, R, se)
            # Problem::new clones the scalar tables
            [row[:] for row in p["demand"]]
            [row[:] for row in p["capacity"]]
            [row[:] for row in p["alpha"]]
            [row[:] for row in p["kind"]]
            _churn_kind_index(L, K, ptr, ei, kind)
            _churn_lpt(R, K, inst, shards)

    mean_i, min_i = bench(incremental, 3, 20)
    mean_b, min_b = bench(rebuild, 3, 20)
    rows.append(dict(name=name, section="churn-epoch", shards=shards,
                     incremental_ms=mean_i * 1e3, rebuild_ms=mean_b * 1e3,
                     incremental_ms_min=min_i * 1e3,
                     rebuild_ms_min=min_b * 1e3,
                     speedup=mean_b / mean_i))
    print(f"churn epoch {name:<20} incremental {mean_i*1e3:9.3f} ms"
          f"   rebuild {mean_b*1e3:9.3f} ms"
          f"   speedup {mean_b/mean_i:6.2f}x")


# ----------------------------------------------------- §Recover model --

def _put_section(out, name, payload):
    """utils::codec v3 section frame: put_str(name) + crc32(payload) +
    length-prefixed payload (zlib.crc32 is the same reflected
    0xEDB88320 IEEE polynomial the hand-rolled Rust table computes)."""
    nb = name.encode()
    out += struct.pack("<Q", len(nb)) + nb
    out += struct.pack("<I", zlib.crc32(payload) & 0xFFFFFFFF)
    out += struct.pack("<Q", len(payload)) + payload


def _freeze_mirror(p, records_len, y, usage):
    """Structural mirror of sim::checkpoint::freeze — pack the run
    snapshot into the utils::codec **PLCK v3** byte layout: the
    magic/version header, then one named, CRC-32-tagged section per
    snapshot piece (driver counters, per-slot records, liveness masks,
    the ClusterState usage grid, the policy's decision tensor, the
    arrivals RNG state), closed by the whole-blob CRC trailer
    `Reader::new` verifies before any field decode.  Every f64 is its
    IEEE bits, which struct '<d' emits byte-identically to
    f64::to_bits."""
    out = bytearray()
    out += struct.pack("<II", 0x4B434C50, 3)          # "PLCK", VERSION 3
    sec = bytearray()                                 # driver section
    for v in (records_len, 0, 0, 0, 0):               # cursor + counters
        sec += struct.pack("<Q", v)
    name = b"OGASCHED"
    sec += struct.pack("<Q", len(name)) + name
    sec += struct.pack("<dQ", 123.456, 0)             # cum reward, clamped
    _put_section(out, "driver", bytes(sec))
    sec = bytearray()
    sec += struct.pack("<Q", records_len)
    for t in range(records_len):                      # SlotRecord stream
        sec += struct.pack("<Qdddd", t, 0.1, 0.2, 0.05, 3.0)
    _put_section(out, "records", bytes(sec))
    _put_section(out, "masks",
                 bytes(p["R"]) + bytes(p["L"]) + bytes(p["L"]))
    sec = bytearray()
    for row in usage:                                 # ClusterState grid
        sec += struct.pack("<%dd" % len(row), *row)
    sec += struct.pack("<dd", 17.0, 0.0)              # total + compensation
    _put_section(out, "ledger", bytes(sec))
    sec = bytearray()
    sec += struct.pack("<Q", len(y))                  # policy section: y
    sec += struct.pack("<%dd" % len(y), *y)
    _put_section(out, "policy", bytes(sec))
    _put_section(out, "arrivals", struct.pack("<4Q", 1, 2, 3, 4))
    out += struct.pack("<I", zlib.crc32(bytes(out)) & 0xFFFFFFFF)  # trailer
    return out


def recover_section(rows, traffic_rows):
    """§Recover: checkpointed execution overhead vs epoch length plus
    kill-and-resume recovery cost, modeled against the measured dense
    (ρ = 0.7, Scenario::default traffic) slot — the regime the new
    `resilient run h50` rows of benches/hot_path.rs run in.  Freeze cost
    is proxy-timed on the structural snapshot mirror; thaw is charged
    equal to freeze (same bytes decoded), and each kill additionally
    replays the slots since the last checkpoint (epoch/2 on average)."""
    name, L, R, K, density = "default 10x128x6", 10, 128, 6, 3.0
    horizon = 50
    p = make_problem(L, R, K, density, seed=2023)
    slot_ms = next(r["dense_ms"] for r in traffic_rows if r["name"] == name)
    rng = random.Random(7)
    y = [rng.uniform(0.0, 1.0) for _ in range(p["E"] * K)]
    usage = [[rng.uniform(0.0, 2.0) for _ in range(K)] for _ in range(R)]
    # average checkpoint packs ~horizon/2 accumulated slot records
    mean_f, min_f = bench(lambda: _freeze_mirror(p, horizon // 2, y, usage),
                          10, 200)
    freeze_ms = mean_f * 1e3
    nockpt_ms = horizon * slot_ms
    rows.append(dict(name=name, section="recover-model", label="nockpt",
                     ckpts=0, freeze_ms=freeze_ms, modeled_ms=nockpt_ms,
                     overhead_pct=0.0))
    for epoch in (1, 5, 17):
        # boundaries 0, epoch, 2·epoch, … < horizon (slot 0 always writes)
        ckpts = 1 + (horizon - 1) // epoch
        modeled = nockpt_ms + ckpts * freeze_ms
        rows.append(dict(name=name, section="recover-model",
                         label=f"epoch{epoch}", ckpts=ckpts,
                         freeze_ms=freeze_ms, modeled_ms=modeled,
                         overhead_pct=(modeled / nockpt_ms - 1.0) * 100))
        print(f"resilient h{horizon} {name:<20} epoch{epoch:<3} "
              f"ckpts {ckpts:3}   freeze {freeze_ms:7.3f} ms   "
              f"overhead {(modeled / nockpt_ms - 1.0) * 100:5.2f}%")
    # kill-and-resume on epoch 5: each kill thaws the latest blob and
    # replays the (epoch/2 expected) slots since it; replayed boundaries
    # re-write their (bit-identical) blobs
    epoch, kills = 5, 2
    ckpts = 1 + (horizon - 1) // epoch
    recover_ms = kills * (freeze_ms + (epoch / 2) * slot_ms + freeze_ms)
    modeled = nockpt_ms + ckpts * freeze_ms + recover_ms
    rows.append(dict(name=name, section="recover-model",
                     label="epoch5 kills", ckpts=ckpts, kills=kills,
                     freeze_ms=freeze_ms, modeled_ms=modeled,
                     overhead_pct=(modeled / nockpt_ms - 1.0) * 100))
    print(f"resilient h{horizon} {name:<20} epoch5 +{kills} kills      "
          f"recover {recover_ms:7.3f} ms   "
          f"overhead {(modeled / nockpt_ms - 1.0) * 100:5.2f}%")


# ------------------------------------------------------ §SStore model --

def sstore_section(rows, traffic_rows):
    """§SStore: the durable self-verifying checkpoint chain, modeled to
    match the `sstore *` rows of benches/hot_path.rs (h50, epoch 5,
    chain depth 5, one kill at slot 41).

    The freeze+put pair: the epoch-5 resilient run with the chain in
    memory (put = blob copy, proxy-timed) vs persisted to disk (put =
    write temp + flush + fsync + atomic rename, really performed
    against a tempdir — fsync dominates, which is exactly the Rust
    story).  The thaw trio: recovery verifies blobs newest→oldest
    (whole-blob CRC-32, really computed), rejects the torn ones, thaws
    the first intact blob (charged one freeze — same bytes decoded)
    and replays/re-writes from the older restore point:

      valid      restore 40: 1 verify, 1 re-run slot, 0 re-writes
      fallback1  restore 35: 2 verifies, 6 re-run slots, 2 re-writes
      fallback3  restore 25: 4 verifies, 16 re-run slots, 4 re-writes
    """
    name, L, R, K, density = "default 10x128x6", 10, 128, 6, 3.0
    horizon, epoch, depth = 50, 5, 5
    p = make_problem(L, R, K, density, seed=2023)
    slot_ms = next(r["dense_ms"] for r in traffic_rows if r["name"] == name)
    rng = random.Random(7)
    y = [rng.uniform(0.0, 1.0) for _ in range(p["E"] * K)]
    usage = [[rng.uniform(0.0, 2.0) for _ in range(K)] for _ in range(R)]
    blob = bytes(_freeze_mirror(p, horizon // 2, y, usage))
    mean_f, _ = bench(lambda: _freeze_mirror(p, horizon // 2, y, usage),
                      10, 200)
    freeze_ms = mean_f * 1e3
    ckpts = 1 + (horizon - 1) // epoch
    base_ms = horizon * slot_ms + ckpts * freeze_ms

    mean_put, _ = bench(lambda: bytes(blob), 10, 200)         # memcpy put
    mem_ms = base_ms + ckpts * mean_put * 1e3
    tmp = tempfile.mkdtemp(prefix="ogasched-sstore-proxy-")

    def disk_put(i=[0]):
        i[0] += 1
        path = os.path.join(tmp, "ckpt-e%08d.plck" % i[0])
        with open(path + ".tmp", "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(path + ".tmp", path)

    mean_disk, _ = bench(disk_put, 3, 40)
    for fn in os.listdir(tmp):
        os.unlink(os.path.join(tmp, fn))
    os.rmdir(tmp)
    disk_ms = base_ms + ckpts * mean_disk * 1e3
    rows.append(dict(name=name, section="sstore-put", backend="mem",
                     blob_bytes=len(blob), put_us=mean_put * 1e6,
                     modeled_ms=mem_ms))
    rows.append(dict(name=name, section="sstore-put", backend="disk",
                     blob_bytes=len(blob), put_us=mean_disk * 1e6,
                     modeled_ms=disk_ms))
    print(f"sstore put {name:<20} mem {mean_put*1e6:8.2f} us/blob   "
          f"disk {mean_disk*1e6:8.2f} us/blob   blob {len(blob)} B")

    mean_v, _ = bench(lambda: zlib.crc32(blob), 10, 200)      # verify walk
    verify_ms = mean_v * 1e3
    for label, verifies, replay_slots, rewrites in (
            ("valid", 1, 1, 0),
            ("fallback1", 2, 6, 2),
            ("fallback3", 4, 16, 4)):
        thaw_ms = (verifies * verify_ms + freeze_ms
                   + replay_slots * slot_ms + rewrites * freeze_ms)
        modeled = mem_ms + thaw_ms
        rows.append(dict(name=name, section="sstore-thaw", label=label,
                         verifies=verifies, replay_slots=replay_slots,
                         rewrites=rewrites, thaw_ms=thaw_ms,
                         modeled_ms=modeled,
                         overhead_pct=(modeled / mem_ms - 1.0) * 100))
        print(f"sstore thaw {label:<10} {name:<20} "
              f"thaw+replay {thaw_ms:8.3f} ms   "
              f"overhead {(modeled / mem_ms - 1.0) * 100:5.2f}%")


def obs_section(rows, sharded_rows):
    """§Obs: model the observability overhead on the sharded sparse slot
    (the `leader slot sparse10 decay shard4 obs=*` rows of
    benches/hot_path.rs).

    Per slot the instrumented pipeline passes 4 + 2·S span sites (slot,
    decide, commit, reward, S shard-commit tasks, S shard-reward tasks).
    Each level's per-site cost is proxy-timed on structural mirrors of
    rust/src/obs:

      off      one level check (relaxed load + branch in Rust);
      summary  off + two monotonic clock reads + a log₂-histogram record
               (bucket index, five integer updates);
      trace    summary + a bounded ring append (slot write + length
               publish).

    The absolute Python per-site costs exaggerate the Rust ones (a
    perf_counter_ns call and an interpreted branch both cost far more
    than Instant::now / an atomic), so the modeled overhead_pct is a
    conservative *upper* bound — the Rust summary target is <2%."""
    level = [2]  # mirrors the AtomicU8; 0 off / 1 summary / 2 trace
    buckets = [0] * 65
    stat = [0, 0, (1 << 64) - 1, 0]          # count, sum, min, max
    ring = []

    def site_off():
        if level[0] == 0:
            return

    def site_summary(trace=False):
        if level[0] == 0:
            return
        t0 = time.perf_counter_ns()
        dur = time.perf_counter_ns() - t0
        buckets[dur.bit_length() if dur else 0] += 1
        stat[0] += 1
        stat[1] += dur
        if dur < stat[2]:
            stat[2] = dur
        if dur > stat[3]:
            stat[3] = dur
        if trace and len(ring) < (1 << 16):
            ring.append((0, 0, 0, 0, t0, dur))

    costs = {}
    level[0] = 0
    costs["off"] = bench(site_off, 200, 20000)[0]
    level[0] = 1
    costs["summary"] = bench(site_summary, 200, 20000)[0]
    level[0] = 2
    costs["trace"] = bench(lambda: site_summary(True), 200, 20000)[0]

    shards = 4
    sites = 4 + 2 * shards
    for name in ("default 10x128x6", "large 100x1024x6"):
        base_ms = next(r["modeled_ms"] for r in sharded_rows
                       if r["name"] == name and r["shards"] == shards)
        for lvl in ("off", "summary", "trace"):
            obs_ms = sites * costs[lvl] * 1e3
            modeled = base_ms + obs_ms
            rows.append(dict(name=name, section="obs-overhead-model",
                             level=lvl, shards=shards, span_sites=sites,
                             site_ns=costs[lvl] * 1e9, obs_ms=obs_ms,
                             modeled_ms=modeled,
                             overhead_pct=(modeled / base_ms - 1.0) * 100))
            print(f"slot sparse10 decay shard{shards} obs={lvl:<8}{name:<20}"
                  f" modeled {modeled:9.3f} ms   overhead "
                  f"{(modeled / base_ms - 1.0) * 100:5.2f}%"
                  f"   ({sites} sites x {costs[lvl]*1e9:6.1f} ns)")


# ---------------------------------------------------- §SPerf-9 model --

def ingest_queue_mirror(n, capacity):
    """Structural mirror of sim::ingest::IngestQueue, single producer:
    ticketed ring push (global ticket draw + slot write + tail publish)
    followed by the merge pop (peek smallest ticket, claim head).  One
    lane, so the k-way merge degenerates to a head increment — the same
    degenerate shape StreamArrivals drives.  The Rust push/pop pair is
    a handful of atomics; the interpreted mirror costs far more per
    event, so the per-event floor derived here is a conservative upper
    bound."""
    ring = [None] * capacity
    head = tail = ticket = 0
    acc = 0
    for i in range(n):
        if tail - head >= capacity:
            continue  # drop-newest
        ring[tail % capacity] = (ticket, i & 63)
        ticket += 1
        tail += 1
    while head < tail:
        _, port = ring[head % capacity]
        head += 1
        acc += port
    return acc


def stream_next_mirror(state, x, L, batch_events, burst, capacity):
    """Structural mirror of StreamArrivals::next — refill bursts through
    the lane, drain into the batcher's pending FIFO, cut one x(t) batch
    of exactly batch_events (leftovers stay pending, as in Rust)."""
    rng, lane, pendq = state
    while len(pendq) < batch_events:
        for _ in range(burst):
            if len(lane) >= capacity:
                break
            lane.append(rng.randrange(L))
        while lane:
            pendq.append(lane.popleft())
    for l in range(L):
        x[l] = 0.0
    for _ in range(batch_events):
        x[pendq.popleft()] += 1.0


def tensor_copy_mirror(src, dst):
    """The overlapped handoff's y_front -> back-buffer publish.  Rust
    pays one |E|*K memcpy; charging it per element here keeps the
    handoff on the same interpreted cost scale as the stage split, so
    the modeled overlap win is again a lower bound."""
    for c in range(len(src)):
        dst[c] = src[c]


# sync_channel(1) work handoff + Done return per slot (send + recv each
# way; same order as a pool dispatch round trip)
PIPELINE_CHANNEL_COSTS = 2


def sperf9_section(rows):
    """§SPerf-9: streaming ingest + the overlapped slot pipeline.

    (a) queue + batch-formation floors, proxy-timed on structural
        mirrors of sim::ingest;
    (b) the overlapped executor (coordinator::pipeline) as depth-1
        software pipelining over the measured §Perf-3 stage split of
        the decay slot.  The leader thread runs batch formation,
        decide (phase A + ascent + projection + publish) and the
        handoff copy; the committer runs commit + merge + reward.
        Steady state is governed by the slower of the two:

          t_lock(b) = next(b) + decide + commit_reward
          t_over(b) = max(next(b) + decide + copy, commit_reward)
                      + 2 * dispatch

        Throughput rows report slots/sec = 1/t and events/sec = b/t at
        each batch shape — the MODELED twin of `ogasched serve`'s
        BENCH_throughput.json (which measures the same pair and reads
        latency from the obs registry's span.slot.ns histogram)."""
    from collections import deque

    # (a) queue-op floor (matches the bench's `ingest queue push+pop` row)
    n_ev = 1024
    mean_q, min_q = bench(lambda: ingest_queue_mirror(n_ev, 4096), 5, 50)
    rows.append(dict(section="ingest-queue", n=n_ev,
                     total_ms=mean_q * 1e3, total_ms_min=min_q * 1e3,
                     per_event_us=mean_q / n_ev * 1e6))
    print(f"ingest queue push+pop 1prod n={n_ev}"
          f"   {mean_q*1e3:9.3f} ms   ({mean_q/n_ev*1e6:6.3f} us/event)")

    # (b) batch formation + the overlap model per scale
    for name, L, R, K, density, warm, iters in [
        ("default 10x128x6", 10, 128, 6, 3.0, 3, 20),
        ("large 100x1024x6", 100, 1024, 6, 3.0, 2, 10),
    ]:
        p = make_problem(L, R, K, density, seed=2023)
        E = p["E"]
        rng = random.Random(41)
        state = (rng, deque(), deque())
        x = [0.0] * L
        base_batch = 32
        mean_n, _ = bench(
            lambda: stream_next_mirror(state, x, L, base_batch, 48, 1024), 5, 100)
        rows.append(dict(section="stream-next", name=name,
                         batch_events=base_batch, next_ms=mean_n * 1e3,
                         per_event_us=mean_n / base_batch * 1e6))
        print(f"stream next batch{base_batch} {name:<20} {mean_n*1e3:9.3f} ms")

        st = sharded_stage_times(p, warm, iters)
        decide = (st["ascent_serial"] + st["ascent_parallel"]
                  + st["project_parallel"] + st["publish_serial"])
        commit_reward = (st["commit_parallel"] + st["merge_serial"]
                         + st["reward_parallel"])
        y_src = [0.5] * (E * K)
        y_dst = [0.0] * (E * K)
        mean_c, _ = bench(lambda: tensor_copy_mirror(y_src, y_dst), 3, 20)
        channel = PIPELINE_CHANNEL_COSTS * DISPATCH_US * 1e-6
        for batch in (32, 128):
            next_t = mean_n * batch / base_batch
            t_lock = next_t + decide + commit_reward
            t_over = max(next_t + decide + mean_c, commit_reward) + channel
            rows.append(dict(
                name=name, section="pipeline-overlap-model", batch_events=batch,
                lockstep_ms=t_lock * 1e3, overlapped_ms=t_over * 1e3,
                next_ms=next_t * 1e3, decide_ms=decide * 1e3,
                commit_reward_ms=commit_reward * 1e3, handoff_ms=mean_c * 1e3,
                lock_slots_per_sec=1.0 / t_lock, over_slots_per_sec=1.0 / t_over,
                lock_events_per_sec=batch / t_lock,
                over_events_per_sec=batch / t_over,
                speedup=t_lock / t_over))
            print(f"pipeline batch{batch} {name:<20}"
                  f" lockstep {t_lock*1e3:9.3f} ms   overlapped {t_over*1e3:9.3f} ms"
                  f"   speedup {t_lock/t_over:6.2f}x")


def write_throughput_json(sperf9_rows, slots=400, shards=4):
    """MODELED stand-in for `ogasched serve`'s BENCH_throughput.json —
    byte-layout-compatible with scripts/check_throughput.py.  Latency
    quantiles are degenerate (p50 = p99 = max = the modeled slot
    period): the model has no variance term; the measured file replaces
    this one wholesale once a toolchain can run `ogasched serve`."""
    runs = []
    for row in sperf9_rows:
        if row.get("section") != "pipeline-overlap-model":
            continue
        if "default" not in row["name"]:
            continue
        batch = row["batch_events"]
        for mode, slot_ms in (("lockstep", row["lockstep_ms"]),
                              ("overlapped", row["overlapped_ms"])):
            slot_s = slot_ms * 1e-3
            elapsed = slots * slot_s
            slot_ns = int(round(slot_s * 1e9))
            runs.append(dict(
                mode=mode, batch_events=batch, slots=slots,
                elapsed_secs=round(elapsed, 6),
                slots_per_sec=round(1.0 / slot_s, 1),
                events_per_sec=round(batch / slot_s, 1),
                events_total=slots * batch, batches_total=slots,
                dropped=0, backpressure_waits=0,
                slot_ns=dict(count=slots, p50=slot_ns, p99=slot_ns,
                             max=slot_ns)))
    doc = dict(
        bench="throughput",
        provenance=("MODELED (scripts/perf_proxy.py SPerf-9): no Rust toolchain "
                    "in this container. Slot periods come from the proxy-timed "
                    "stage split + the depth-1 overlap model t_over = "
                    "max(next + decide + copy, commit_reward) + channel; "
                    "latency quantiles are degenerate (no variance term) and "
                    "counters assume the lossless same-thread refill (dropped "
                    "= waits = 0). Regenerate the measured file with "
                    "`ogasched serve --slots 400 --batch-shapes 32,128` — it "
                    "reads real p50/p99/max from the obs registry's "
                    "span.slot.ns histogram."),
        policy="ogasched", slots=slots, shards=shards, backpressure=True,
        runs=runs)
    with open("BENCH_throughput.json", "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print("wrote BENCH_throughput.json")


def main():
    layout_rows = []
    layout_section(layout_rows)
    pipeline_rows = []
    pipeline_section(pipeline_rows)
    sharded_rows = []
    sharded_section(sharded_rows)
    perf4_rows = []
    perf4_section(perf4_rows)
    perf5_rows = []
    perf5_objective_section(perf5_rows)
    perf5_kernel_section(perf5_rows)
    traffic_rows = []
    traffic_section(traffic_rows)
    churn_rows = []
    churn_section(churn_rows)
    recover_rows = []
    recover_section(recover_rows, traffic_rows)
    sstore_rows = []
    sstore_section(sstore_rows, traffic_rows)
    obs_rows = []
    obs_section(obs_rows, sharded_rows)
    sperf9_rows = []
    sperf9_section(sperf9_rows)
    with open("perf_proxy.json", "w") as f:
        json.dump(dict(layout=layout_rows, pipeline=pipeline_rows,
                       sharded=sharded_rows, perf4=perf4_rows,
                       perf5=perf5_rows, traffic=traffic_rows,
                       churn=churn_rows, recover=recover_rows,
                       sstore=sstore_rows, obs=obs_rows,
                       sperf9=sperf9_rows), f, indent=2)
    print("wrote perf_proxy.json")
    write_throughput_json(sperf9_rows)

    # refresh the cross-PR perf record with proxy provenance (overwritten
    # by the first real `cargo bench --bench hot_path` run)
    entries = []
    for row in layout_rows:
        entries.append(dict(name=f"dense-ref OGA step {row['name']}", iters=0,
                            ns_per_op=round(row["dense_ms"] * 1e6, 1),
                            ns_per_op_min=round(row["dense_ms_min"] * 1e6, 1),
                            std_ns=0.0))
        entries.append(dict(name=f"native OGA step   {row['name']}", iters=0,
                            ns_per_op=round(row["csr_ms"] * 1e6, 1),
                            ns_per_op_min=round(row["csr_ms_min"] * 1e6, 1),
                            std_ns=0.0))
    for row in pipeline_rows:
        sched = row["schedule"]
        entries.append(dict(
            name=f"leader slot sparse10 {sched} full {row['name']}", iters=0,
            ns_per_op=round(row["pr1_ms"] * 1e6, 1),
            ns_per_op_min=round(row["pr1_ms_min"] * 1e6, 1),
            std_ns=0.0))
        entries.append(dict(
            name=f"leader slot sparse10 {sched} incr {row['name']}", iters=0,
            ns_per_op=round(row["pr2_ms"] * 1e6, 1),
            ns_per_op_min=round(row["pr2_ms_min"] * 1e6, 1),
            std_ns=0.0))
    for row in sharded_rows:
        entries.append(dict(
            name=f"leader slot sparse10 decay shard{row['shards']} {row['name']}",
            iters=0,
            ns_per_op=round(row["modeled_ms"] * 1e6, 1),
            ns_per_op_min=round(row["modeled_ms"] * 1e6, 1),
            std_ns=0.0))
    for row in perf5_rows:
        if row["section"] == "sharded-objective-model" and "large" in row["name"]:
            # matches benches/hot_path.rs's solve_oracle section: 5
            # iterations per timed op; the §Perf-5 model (objective +
            # phase A sharded) supersedes the §Perf-4 rows — the Rust
            # solve now runs the sharded objective
            entries.append(dict(
                name=f"solve_oracle 5it oracle shard{row['shards']} {row['name']}",
                iters=0,
                ns_per_op=round(row["modeled_ms"] * 5 * 1e6, 1),
                ns_per_op_min=round(row["modeled_ms"] * 5 * 1e6, 1),
                std_ns=0.0))
        elif row["section"] == "sharded-objective-eval" and "large" in row["name"]:
            entries.append(dict(
                name=f"oracle objective shard{row['shards']} {row['name']}",
                iters=0,
                ns_per_op=round(row["modeled_ms"] * 1e6, 1),
                ns_per_op_min=round(row["modeled_ms"] * 1e6, 1),
                std_ns=0.0))
        elif row["section"] == "kernel-lane-model" and row["kind"] != "mean":
            n = row["n"]
            entries.append(dict(
                name=f"kernel {row['kernel']} ref {row['kind']} n={n}",
                iters=0,
                ns_per_op=round(row["scalar_ms"] * 1e6, 1),
                ns_per_op_min=round(row["scalar_ms_min"] * 1e6, 1),
                std_ns=0.0))
            entries.append(dict(
                name=f"kernel {row['kernel']} lane {row['kind']} n={n}",
                iters=0,
                ns_per_op=round(row["modeled_lane_ms"] * 1e6, 1),
                ns_per_op_min=round(row["modeled_lane_ms"] * 1e6, 1),
                std_ns=0.0))
    for row in churn_rows:
        entries.append(dict(
            name=f"churn epoch incremental {row['name']}", iters=0,
            ns_per_op=round(row["incremental_ms"] * 1e6, 1),
            ns_per_op_min=round(row["incremental_ms_min"] * 1e6, 1),
            std_ns=0.0))
        entries.append(dict(
            name=f"churn epoch rebuild {row['name']}", iters=0,
            ns_per_op=round(row["rebuild_ms"] * 1e6, 1),
            ns_per_op_min=round(row["rebuild_ms_min"] * 1e6, 1),
            std_ns=0.0))
    for row in recover_rows:
        entries.append(dict(
            name=f"resilient run h50 {row['label']} {row['name']}", iters=0,
            ns_per_op=round(row["modeled_ms"] * 1e6, 1),
            ns_per_op_min=round(row["modeled_ms"] * 1e6, 1),
            std_ns=0.0))
    for row in sstore_rows:
        if row["section"] == "sstore-put":
            bench_name = (f"sstore freeze+put {row['backend']} h50 epoch5 "
                          f"{row['name']}")
        else:
            bench_name = f"sstore thaw {row['label']} h50 epoch5 {row['name']}"
        entries.append(dict(
            name=bench_name, iters=0,
            ns_per_op=round(row["modeled_ms"] * 1e6, 1),
            ns_per_op_min=round(row["modeled_ms"] * 1e6, 1),
            std_ns=0.0))
    for row in obs_rows:
        if "large" in row["name"]:
            entries.append(dict(
                name=(f"leader slot sparse10 decay shard{row['shards']} "
                      f"obs={row['level']} {row['name']}"),
                iters=0,
                ns_per_op=round(row["modeled_ms"] * 1e6, 1),
                ns_per_op_min=round(row["modeled_ms"] * 1e6, 1),
                std_ns=0.0))
    for row in perf4_rows:
        if row["section"] == "lineup-budget-model":
            # matches the run_lineup bench rows: 50 slots per timed op
            entries.append(dict(
                name=f"run_lineup 5pol h50 budget {row['split']} {row['name']}",
                iters=0,
                ns_per_op=round(row["modeled_ms"] * 50 * 1e6, 1),
                ns_per_op_min=round(row["modeled_ms"] * 50 * 1e6, 1),
                std_ns=0.0))
    for row in sperf9_rows:
        if row["section"] == "ingest-queue":
            entries.append(dict(
                name=f"ingest queue push+pop 1prod n={row['n']}", iters=0,
                ns_per_op=round(row["total_ms"] * 1e6, 1),
                ns_per_op_min=round(row["total_ms_min"] * 1e6, 1),
                std_ns=0.0))
        elif row["section"] == "stream-next" and "default" in row["name"]:
            entries.append(dict(
                name=f"stream next batch{row['batch_events']} {row['name']}",
                iters=0,
                ns_per_op=round(row["next_ms"] * 1e6, 1),
                ns_per_op_min=round(row["next_ms"] * 1e6, 1),
                std_ns=0.0))
        elif (row["section"] == "pipeline-overlap-model"
              and "default" in row["name"]):
            # matches the bench's pipeline pair: 40 slots per timed op
            for mode, key in (("lockstep", "lockstep_ms"),
                              ("overlapped", "overlapped_ms")):
                entries.append(dict(
                    name=(f"pipeline h40 {mode} batch{row['batch_events']} "
                          f"shard4 {row['name']}"),
                    iters=0,
                    ns_per_op=round(row[key] * 40 * 1e6, 1),
                    ns_per_op_min=round(row[key] * 40 * 1e6, 1),
                    std_ns=0.0))
    doc = dict(
        bench="hot_path",
        note=("python structural proxy (scripts/perf_proxy.py): this container "
              "has no Rust toolchain; overwrite by running `cargo bench --bench "
              "hot_path`. Ratios are a conservative lower bound for the Rust "
              "speedups (see EXPERIMENTS.md §Perf, §Perf-2). NB the PR-2 proxy "
              "re-measured the layout rows with updated proxy code (kind-"
              "batched csr step, allocation-free projection fast path on both "
              "sides), so dense-ref/native rows are not comparable to the "
              "PR-1 committed values — harness change, not a perf change. "
              "The shard{1,2,4,8} rows are MODELED (Amdahl over the measured "
              "serial/parallel stage split + 4x5us pool dispatch, EXPERIMENTS.md "
              "SPerf-3), not timed: the proxy is single-threaded Python; the "
              "real rows come from benches/hot_path.rs's ShardedLeader section. "
              "The solve_oracle shard{1,2,4,8} and run_lineup budget rows are "
              "likewise MODELED (SPerf-5 supersedes the SPerf-4 oracle shape: "
              "t(S) = norm + (phase_a + grad + ascent + proj + objective)/S "
              "per iteration now that the objective and phase A are sharded; "
              "ceil(N/runs) waves of the sharded slot for the lineup). The "
              "SPerf-5 `kernel * lane` rows divide the measured scalar row by "
              "the documented op-cost lane model (f64x4; ln lane-serial) — "
              "time the real pair with `cargo bench --bench hot_path` with "
              "and without `--features simd`. The SChurn `churn epoch` pair "
              "(incremental apply + ShardPlan refresh vs from-scratch Problem "
              "+ LPT rebuild, two editions per op) is a proxy-timed "
              "structural mirror of the same stages in Rust. The SRecover "
              "`resilient run h50` rows are MODELED (horizon x the measured "
              "dense slot + a proxy-timed structural freeze mirror per "
              "checkpoint boundary; kills add thaw + epoch/2 replay slots, "
              "EXPERIMENTS.md SRecover) — the real rows come from "
              "benches/hot_path.rs's run_resilient_scenario section. The "
              "SStore `sstore freeze+put {mem,disk}` and `sstore thaw "
              "{valid,fallback1,fallback3}` rows are MODELED on the same "
              "split plus a proxy-timed PLCK v3 freeze mirror (per-section "
              "+ whole-blob CRC-32), a really-performed write+fsync+rename "
              "put against a tempdir for the disk row, and per-fallback "
              "verify walks + replay/re-write charges (EXPERIMENTS.md "
              "SStore) — the real rows come from benches/hot_path.rs's "
              "SStore section. The "
              "SObs `obs={off,summary,trace}` rows add a per-span-site cost "
              "proxy-timed on mirrors of rust/src/obs (clock reads + log2 "
              "histogram record, + ring append at trace) to the modeled "
              "shard4 slot; Python per-site costs exaggerate the Rust "
              "atomics, so the overhead_pct is an upper bound — the real "
              "rows come from benches/hot_path.rs's SObs section (target "
              "<2% at summary). The SPerf-9 `ingest queue` and `stream next` "
              "rows are proxy-timed structural mirrors of sim::ingest "
              "(interpreted per-event cost far exceeds the Rust atomics, so "
              "they upper-bound the real floor); the `pipeline h40 "
              "{lockstep,overlapped}` rows are MODELED from the measured "
              "decay stage split via the depth-1 overlap shape t_over = "
              "max(next + decide + copy, commit_reward) + channel "
              "(EXPERIMENTS.md SPerf-9) — the real pair comes from "
              "benches/hot_path.rs's SPerf-9 section and, at figure scale, "
              "`ogasched serve` -> BENCH_throughput.json."),
        entries=entries,
    )
    with open("BENCH_hot_path.json", "w") as f:
        json.dump(doc, f, indent=2)
    print("wrote BENCH_hot_path.json")


if __name__ == "__main__":
    main()
