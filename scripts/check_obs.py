#!/usr/bin/env python3
"""Validate the obs exporters' output (EXPERIMENTS.md §Obs).

CI runs a short traced lineup (`compare --obs trace`) and feeds the two
files it writes through this script:

  check_obs.py results/obs_events.jsonl results/obs_trace.json

With `--require-recovery` (the §SStore storage-fault job, whose traced
run drives the resilient driver), additionally validates the recovery
counter algebra in the JSONL stream:

  * `recover.ckpts_written` is present and equals
    `recover.ckpts_fresh + recover.ckpts_rewritten` (the telemetry
    split — a write is fresh xor a replay re-write, never both);
  * `recover.blobs_rejected >= recover.thaw_fallbacks` (every fallback
    walked past at least one rejected blob, so no damaged blob can
    have been thawed silently).

Checks, matching the schema contract of `rust/src/obs/export.rs`:

  * the JSONL stream starts with a `meta` record carrying the
    `ogasched-obs` schema name and version 1, every line parses as
    JSON, and every record type carries its required fields;
  * at least one span record and the slot-phase span names are present
    (a traced lineup must have produced them);
  * the Chrome trace file is valid JSON of the `traceEvents` object
    form Perfetto loads, the array is non-empty, every event has a
    known phase (`M`/`X`/`i`) with the fields that phase requires, and
    every `X`/`i` event's `tid` was introduced by a `thread_name`
    metadata record.

Exits non-zero with a message on the first violation.
"""

import json
import sys

REQUIRED = {
    "meta": {"schema", "version"},
    "span": {"seq", "thread", "kind", "slot", "shard", "gen", "ts_ns", "dur_ns"},
    "dropped": {"thread", "count"},
    "counter": {"name", "value"},
    "gauge": {"name", "value"},
    "histogram": {"name", "count", "sum", "min", "max", "p50", "p99"},
}

SLOT_PHASES = {"slot", "slot.decide", "slot.commit", "slot.reward"}


def fail(msg):
    print(f"check_obs: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_jsonl(path):
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln]
    if not lines:
        fail(f"{path}: empty")
    records = []
    for i, ln in enumerate(lines):
        try:
            rec = json.loads(ln)
        except json.JSONDecodeError as e:
            fail(f"{path}:{i + 1}: not JSON: {e}")
        kind = rec.get("record")
        if kind not in REQUIRED:
            fail(f"{path}:{i + 1}: unknown record type {kind!r}")
        missing = REQUIRED[kind] - rec.keys()
        if missing:
            fail(f"{path}:{i + 1}: {kind} record missing {sorted(missing)}")
        records.append(rec)
    meta = records[0]
    if meta["record"] != "meta":
        fail(f"{path}: first record is {meta['record']!r}, not meta")
    if meta["schema"] != "ogasched-obs" or meta["version"] != 1:
        fail(f"{path}: unexpected schema header {meta}")
    spans = [r for r in records if r["record"] == "span"]
    if not spans:
        fail(f"{path}: a traced run produced no span records")
    kinds = {s["kind"] for s in spans}
    missing_phases = SLOT_PHASES - kinds
    if missing_phases:
        fail(f"{path}: slot phases missing from trace: {sorted(missing_phases)}")
    seqs = [s["seq"] for s in spans]
    if seqs != list(range(len(seqs))):
        fail(f"{path}: span seq numbers are not 0..{len(seqs) - 1} in order")
    hists = [r for r in records if r["record"] == "histogram"]
    if not any(h["name"] == "span.slot.ns" and h["count"] > 0 for h in hists):
        fail(f"{path}: no populated span.slot.ns histogram")
    for h in hists:
        if h["count"] > 0 and not (
            h["min"] <= h["p50"] <= h["p99"] <= h["max"]
        ):
            fail(f"{path}: histogram {h['name']} quantiles out of order: {h}")
    print(f"check_obs: {path}: OK ({len(spans)} spans, {len(hists)} histograms)")
    return records


def check_recovery_counters(path, records):
    counters = {r["name"]: r["value"] for r in records if r["record"] == "counter"}
    written = counters.get("recover.ckpts_written")
    if written is None:
        fail(f"{path}: --require-recovery but no recover.ckpts_written counter")
    fresh = counters.get("recover.ckpts_fresh", 0)
    rewritten = counters.get("recover.ckpts_rewritten", 0)
    if written != fresh + rewritten:
        fail(
            f"{path}: checkpoint-write split broken: "
            f"written={written} != fresh={fresh} + rewritten={rewritten}"
        )
    rejected = counters.get("recover.blobs_rejected", 0)
    fallbacks = counters.get("recover.thaw_fallbacks", 0)
    if rejected < fallbacks:
        fail(
            f"{path}: thaw fallbacks ({fallbacks}) exceed rejected blobs "
            f"({rejected}) — a damaged blob was thawed silently"
        )
    print(
        f"check_obs: {path}: recovery counters OK "
        f"(written={written} = fresh {fresh} + rewrites {rewritten}; "
        f"rejected={rejected} >= fallbacks={fallbacks})"
    )


def check_chrome(path):
    with open(path) as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            fail(f"{path}: not JSON: {e}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents missing or empty")
    named_tids = set()
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") != "thread_name" or "name" not in ev.get("args", {}):
                fail(f"{path}: event {i}: malformed metadata record {ev}")
            named_tids.add(ev.get("tid"))
        elif ph == "X":
            for field in ("name", "ts", "dur", "pid", "tid"):
                if field not in ev:
                    fail(f"{path}: event {i}: X event missing {field!r}")
        elif ph == "i":
            for field in ("name", "ts", "s", "pid", "tid"):
                if field not in ev:
                    fail(f"{path}: event {i}: i event missing {field!r}")
        else:
            fail(f"{path}: event {i}: unknown phase {ph!r}")
        if ph in ("X", "i") and ev["tid"] not in named_tids:
            fail(f"{path}: event {i}: tid {ev['tid']} has no thread_name record")
    durations = sum(1 for ev in events if ev.get("ph") == "X")
    if durations == 0:
        fail(f"{path}: no duration (ph=X) events")
    print(f"check_obs: {path}: OK ({len(events)} events, {durations} spans)")


def main():
    argv = sys.argv[1:]
    require_recovery = "--require-recovery" in argv
    argv = [a for a in argv if a != "--require-recovery"]
    if len(argv) != 2:
        fail("usage: check_obs.py [--require-recovery] <obs_events.jsonl> <obs_trace.json>")
    records = check_jsonl(argv[0])
    if require_recovery:
        check_recovery_counters(argv[0], records)
    check_chrome(argv[1])
    print("check_obs: PASS")


if __name__ == "__main__":
    main()
