#!/usr/bin/env python3
"""Validate BENCH_throughput.json (EXPERIMENTS.md §SPerf-9).

CI runs a smoke `ogasched serve --slots ... --batch-shapes A,B` and
feeds the file it writes through this script:

  check_throughput.py BENCH_throughput.json [--measured]

Checks, matching the schema `cmd_serve` (rust/src/main.rs) emits and
`scripts/perf_proxy.py::write_throughput_json` mirrors:

  * top-level keys: bench == "throughput", a non-empty provenance
    string, policy, slots > 0, shards >= 1, backpressure bool, runs[];
  * every run row carries mode/batch_events/slots/elapsed_secs/
    slots_per_sec/events_per_sec/events_total/batches_total/dropped/
    backpressure_waits and a slot_ns object with count/p50/p99/max,
    with the right JSON types and p50 <= p99 <= max;
  * both pipeline modes are present, at >= 2 batch shapes, and every
    (mode, batch_events) pair appears exactly once;
  * per row: batches_total == slots, events_total >= slots *
    batch_events (the stream forms full batches; the refill may push
    ahead), and the throughput fields are positive;
  * lockstep and overlapped rows at the same batch shape agree on
    events_total — the bitwise pipeline-parity contract seen through
    the integer counters;
  * with --measured (the CI smoke path): provenance starts with
    "measured" and every slot_ns histogram has count == slots and a
    positive p50 — the latencies really came from the obs registry.

Exits non-zero with a message on the first violation.
"""

import json
import sys

RUN_FIELDS = {
    "mode": str,
    "batch_events": int,
    "slots": int,
    "elapsed_secs": (int, float),
    "slots_per_sec": (int, float),
    "events_per_sec": (int, float),
    "events_total": int,
    "batches_total": int,
    "dropped": int,
    "backpressure_waits": int,
    "slot_ns": dict,
}

SLOT_NS_FIELDS = ("count", "p50", "p99", "max")


def fail(msg):
    print(f"check_throughput: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check(path, measured):
    with open(path) as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            fail(f"{path}: not JSON: {e}")
    if doc.get("bench") != "throughput":
        fail(f"{path}: bench is {doc.get('bench')!r}, not 'throughput'")
    provenance = doc.get("provenance")
    if not isinstance(provenance, str) or not provenance:
        fail(f"{path}: missing provenance string")
    if measured and not provenance.startswith("measured"):
        fail(f"{path}: --measured run has provenance {provenance[:40]!r}...")
    if not isinstance(doc.get("policy"), str):
        fail(f"{path}: missing policy")
    slots = doc.get("slots")
    if not isinstance(slots, int) or slots <= 0:
        fail(f"{path}: slots must be a positive integer, got {slots!r}")
    if not isinstance(doc.get("shards"), int) or doc["shards"] < 1:
        fail(f"{path}: shards must be an integer >= 1")
    if not isinstance(doc.get("backpressure"), bool):
        fail(f"{path}: backpressure must be a bool")
    runs = doc.get("runs")
    if not isinstance(runs, list) or not runs:
        fail(f"{path}: runs missing or empty")

    seen = set()
    by_shape = {}
    for i, run in enumerate(runs):
        ctx = f"{path}: runs[{i}]"
        if not isinstance(run, dict):
            fail(f"{ctx}: not an object")
        for field, ty in RUN_FIELDS.items():
            if field not in run:
                fail(f"{ctx}: missing {field!r}")
            if not isinstance(run[field], ty) or isinstance(run[field], bool):
                fail(f"{ctx}: {field} has type {type(run[field]).__name__}")
        if run["mode"] not in ("lockstep", "overlapped"):
            fail(f"{ctx}: unknown mode {run['mode']!r}")
        key = (run["mode"], run["batch_events"])
        if key in seen:
            fail(f"{ctx}: duplicate (mode, batch_events) {key}")
        seen.add(key)
        if run["slots"] != slots:
            fail(f"{ctx}: slots {run['slots']} != top-level {slots}")
        if run["batch_events"] <= 0:
            fail(f"{ctx}: batch_events must be positive")
        for field in ("elapsed_secs", "slots_per_sec", "events_per_sec"):
            if run[field] <= 0:
                fail(f"{ctx}: {field} must be positive, got {run[field]}")
        if run["batches_total"] != slots:
            fail(f"{ctx}: batches_total {run['batches_total']} != slots {slots}")
        if run["events_total"] < slots * run["batch_events"]:
            fail(f"{ctx}: events_total {run['events_total']} below "
                 f"slots * batch_events = {slots * run['batch_events']}")
        if run["dropped"] < 0 or run["backpressure_waits"] < 0:
            fail(f"{ctx}: negative queue counters")
        sn = run["slot_ns"]
        for field in SLOT_NS_FIELDS:
            if not isinstance(sn.get(field), int) or isinstance(sn.get(field), bool):
                fail(f"{ctx}: slot_ns.{field} must be an integer, got "
                     f"{sn.get(field)!r}")
        if not sn["p50"] <= sn["p99"] <= sn["max"]:
            fail(f"{ctx}: slot_ns quantiles out of order: {sn}")
        if measured:
            if sn["count"] != slots:
                fail(f"{ctx}: measured slot_ns.count {sn['count']} != {slots} "
                     "(histogram not reset per run?)")
            if sn["p50"] <= 0:
                fail(f"{ctx}: measured p50 must be positive")
        by_shape.setdefault(run["batch_events"], {})[run["mode"]] = run

    shapes = sorted(by_shape)
    if len(shapes) < 2:
        fail(f"{path}: need >= 2 batch shapes, got {shapes}")
    for shape, modes in by_shape.items():
        missing = {"lockstep", "overlapped"} - modes.keys()
        if missing:
            fail(f"{path}: batch_events={shape} missing modes {sorted(missing)}")
        lock, over = modes["lockstep"], modes["overlapped"]
        if lock["events_total"] != over["events_total"]:
            fail(f"{path}: batch_events={shape}: events_total diverged across "
                 f"modes ({lock['events_total']} vs {over['events_total']}) — "
                 "pipeline parity violated")
    print(f"check_throughput: {path}: OK ({len(runs)} runs, "
          f"shapes {shapes}, slots {slots})")


def main():
    argv = sys.argv[1:]
    measured = "--measured" in argv
    argv = [a for a in argv if a != "--measured"]
    if len(argv) != 1:
        fail("usage: check_throughput.py <BENCH_throughput.json> [--measured]")
    check(argv[0], measured)
    print("check_throughput: PASS")


if __name__ == "__main__":
    main()
