//! Sec. 4.3 large-scale validation (Fig. 5 setting): 100 job types,
//! 1024 computing instances, β ∈ [0.01, 0.015], contention 5.
//!
//! The paper runs T = 10000 (15 hours on their testbed); default here is
//! T = 500 so the example completes in minutes — set OGASCHED_T=10000 to
//! regenerate the full figure (or use `cargo bench --bench
//! fig5_large_scale`).
//!
//!     cargo run --release --example large_scale

use ogasched::config::Scenario;
use ogasched::metrics;
use ogasched::sim;
use ogasched::utils::table::Table;

fn main() {
    let horizon: usize = std::env::var("OGASCHED_T")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500);
    let mut scenario = Scenario::large_scale();
    scenario.horizon = horizon;
    println!(
        "large-scale: |L|={} |R|={} K={} T={} beta=[{},{}] (unit-consistent) contention={}",
        scenario.num_ports,
        scenario.num_instances,
        scenario.num_resources,
        scenario.horizon,
        scenario.beta_range.0,
        scenario.beta_range.1,
        scenario.contention
    );

    let results = sim::run_paper_lineup(&scenario);
    let oga = &results[0].clone();
    let mut table = Table::new(&["policy", "avg reward", "OGA improvement", "slots/s"]);
    for run in &results {
        let imp = if run.policy == "OGASCHED" {
            "-".into()
        } else {
            format!("{:+.2}%", metrics::improvement_pct(oga, run))
        };
        table.push(&[
            run.policy.clone(),
            format!("{:.2}", run.avg_reward()),
            imp,
            format!("{:.0}", run.throughput()),
        ]);
    }
    println!("{}", table.render());
    println!("paper: OGASCHED's superiority is preserved in large-scale scenarios.");
}
