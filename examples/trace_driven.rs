//! End-to-end driver (the repository's headline experiment).
//!
//! Synthesizes the Alibaba-like cluster of the paper's Tab. 2 defaults
//! (128 instances, 6 device types, 10 job types), then runs all five
//! policies for T slots through the L3 coordinator.  OGASCHED runs
//! TWICE: once with the native Rust kernels and once with its per-slot
//! compute executed by the **AOT-compiled XLA artifact via PJRT**
//! (`OGASCHED-HLO`) — proving that all three layers (Pallas kernel →
//! JAX model → Rust coordinator) compose on the request path.
//!
//! Reports the paper's headline metric — average-reward improvement of
//! OGASCHED over DRF / FAIRNESS / BINPACKING / SPREADING (paper:
//! 11.33 / 7.75 / 13.89 / 13.44 %) — plus hot-path latency for both
//! OGASCHED implementations.  Results are recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example trace_driven
//!     (OGASCHED_T=8000 for the full paper horizon)

use ogasched::config::Scenario;
use ogasched::coordinator::{Leader, RunResult};
use ogasched::metrics;
use ogasched::runtime::{default_dir, HloOgaSched, Manifest};
use ogasched::schedulers::{paper_lineup, Policy};
use ogasched::sim::arrivals::Bernoulli;
use ogasched::traces::synthesize;
use ogasched::utils::table::Table;

fn main() {
    let horizon: usize = std::env::var("OGASCHED_T")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2000);
    let mut scenario = Scenario::default();
    scenario.horizon = horizon;
    let problem = synthesize(&scenario);
    println!(
        "trace-driven e2e: |L|={} |R|={} K={} T={} rho={} contention={} \
         (graph density {:.2})",
        scenario.num_ports,
        scenario.num_instances,
        scenario.num_resources,
        scenario.horizon,
        scenario.arrival_prob,
        scenario.contention,
        problem.graph.density(),
    );

    // --- the paper lineup (native OGASCHED + 4 baselines) ---
    let mut lineup = paper_lineup(&problem, scenario.eta0, scenario.decay, scenario.parallel);
    let mut results: Vec<RunResult> = lineup
        .iter_mut()
        .map(|policy| {
            let mut leader = Leader::new(&problem);
            let mut arrivals = Bernoulli::uniform(
                problem.num_ports(),
                scenario.arrival_prob,
                scenario.seed ^ 0xA5A5,
            );
            policy.reset(&problem);
            leader.run(policy.as_mut(), &mut arrivals, scenario.horizon)
        })
        .collect();

    // --- OGASCHED through the PJRT-compiled artifact (layer bridge) ---
    match Manifest::load(default_dir()) {
        Ok(manifest) => {
            let mut hlo =
                HloOgaSched::new(&manifest, &problem, scenario.eta0, scenario.decay)
                    .expect("load + compile HLO artifact");
            println!("OGASCHED-HLO: compiled artifact bucket `{}`", hlo.bucket_name());
            let mut leader = Leader::new(&problem);
            let mut arrivals = Bernoulli::uniform(
                problem.num_ports(),
                scenario.arrival_prob,
                scenario.seed ^ 0xA5A5,
            );
            hlo.reset(&problem);
            results.push(leader.run(&mut hlo, &mut arrivals, scenario.horizon));
        }
        Err(e) => {
            eprintln!("skipping OGASCHED-HLO ({e}); run `make artifacts`");
        }
    }

    let oga = results[0].clone();
    let mut table = Table::new(&[
        "policy",
        "avg reward",
        "cumulative",
        "OGA improvement",
        "slots/s",
        "ms/slot",
    ]);
    for run in &results {
        let imp = if run.policy.starts_with("OGASCHED") {
            "-".into()
        } else {
            format!("{:+.2}%", metrics::improvement_pct(&oga, run))
        };
        table.push(&[
            run.policy.clone(),
            format!("{:.2}", run.avg_reward()),
            format!("{:.1}", run.cumulative_reward),
            imp,
            format!("{:.0}", run.throughput()),
            format!("{:.3}", 1e3 * run.elapsed_secs / run.records.len().max(1) as f64),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper headline: OGASCHED beats DRF/FAIRNESS/BINPACKING/SPREADING by \
         11.33/7.75/13.89/13.44 % (T=8000)"
    );

    // parity of the two OGASCHED implementations (native f64 vs HLO f32)
    if let Some(hlo) = results.iter().find(|r| r.policy == "OGASCHED-HLO") {
        let drift =
            (hlo.avg_reward() - oga.avg_reward()).abs() / oga.avg_reward().abs().max(1e-9);
        println!(
            "native-vs-HLO avg reward drift: {:.4}% (f32 artifact vs f64 native)",
            100.0 * drift
        );
    }
}
