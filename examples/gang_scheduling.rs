//! Sec. 3.5 extension demo: Gang Scheduling with the All-Or-Nothing
//! property.  Each job type is split into task components; a job only
//! launches when at least m_l components receive resources.  The policy
//! is subgradient ascent on the convex relaxation + gang restoration
//! (see `schedulers::gang`).
//!
//! Also demos the Sec. 3.4 multi-arrival extension on the same cluster.
//!
//!     cargo run --release --example gang_scheduling

use ogasched::config::Scenario;
use ogasched::coordinator::Leader;
use ogasched::schedulers::gang::{GangOga, GangSpec};
use ogasched::schedulers::{MultiArrivalOga, OgaSched, Policy};
use ogasched::ExecBudget;
use ogasched::sim::arrivals::{Bernoulli, MultiCount};
use ogasched::traces::synthesize;
use ogasched::utils::table::Table;

fn main() {
    let mut scenario = Scenario::small();
    scenario.horizon = 400;
    let problem = synthesize(&scenario);
    println!(
        "gang/multi-arrival demo: |L|={} |R|={} K={} T={}",
        scenario.num_ports, scenario.num_instances, scenario.num_resources, scenario.horizon
    );

    // --- gang scheduling: 3 components per job, min 2 must schedule ---
    let specs: Vec<GangSpec> = (0..problem.num_ports())
        .map(|l| GangSpec {
            demands: (0..3)
                .map(|_| {
                    (0..problem.num_resources)
                        .map(|k| problem.demand_at(l, k) / 3.0)
                        .collect()
                })
                .collect(),
            min_tasks: 2,
        })
        .collect();
    let mut gang = GangOga::new(&problem, &specs, scenario.eta0, scenario.decay, ExecBudget::auto());
    let mut leader = Leader::new(&problem);
    let mut arrivals =
        Bernoulli::uniform(problem.num_ports(), scenario.arrival_prob, 11);
    let gang_run = leader.run(&mut gang, &mut arrivals, scenario.horizon);

    // --- plain OGASCHED on the same trajectory for reference ---
    let mut plain = OgaSched::new(&problem, scenario.eta0, scenario.decay, ExecBudget::auto());
    let mut leader = Leader::new(&problem);
    let mut arrivals =
        Bernoulli::uniform(problem.num_ports(), scenario.arrival_prob, 11);
    plain.reset(&problem);
    let plain_run = leader.run(&mut plain, &mut arrivals, scenario.horizon);

    // --- multi-arrival (Sec. 3.4): up to 3 jobs per port per slot ---
    let copies = vec![3usize; problem.num_ports()];
    let mut multi =
        MultiArrivalOga::new(&problem, &copies, scenario.eta0, scenario.decay, ExecBudget::auto());
    let mut leader = Leader::new(&problem);
    let mut counts = MultiCount::new(0.4, 3, 13);
    let multi_run = leader.run(&mut multi, &mut counts, scenario.horizon);

    let mut table = Table::new(&["variant", "avg reward", "cumulative"]);
    for run in [&plain_run, &gang_run, &multi_run] {
        table.push(&[
            run.policy.clone(),
            format!("{:.2}", run.avg_reward()),
            format!("{:.1}", run.cumulative_reward),
        ]);
    }
    println!("{}", table.render());
    println!(
        "gang vs plain gap: the all-or-nothing restoration withholds partial \
         jobs, so the gang variant trades reward for the launch guarantee \
         (Sec. 3.5 notes the non-convex problem is strictly harder)."
    );
}
