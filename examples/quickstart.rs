//! Quickstart: a 16-instance cluster, 4 job types, 300 slots.
//! Runs OGASCHED against the paper's four baselines and prints the
//! reward table plus OGASCHED's improvement percentages.
//!
//!     cargo run --release --example quickstart

use ogasched::config::Scenario;
use ogasched::metrics;
use ogasched::sim;
use ogasched::utils::table::Table;

fn main() {
    let mut scenario = Scenario::small();
    scenario.horizon = 1500; // long enough for the online learner to pass the reactive heuristics
    println!(
        "cluster: |L|={} |R|={} K={} T={} rho={} contention={}",
        scenario.num_ports,
        scenario.num_instances,
        scenario.num_resources,
        scenario.horizon,
        scenario.arrival_prob,
        scenario.contention
    );

    let results = sim::run_paper_lineup(&scenario);
    let oga = &results[0];

    let mut table = Table::new(&["policy", "avg reward", "cumulative", "vs OGASCHED"]);
    for run in &results {
        let delta = if run.policy == "OGASCHED" {
            "-".to_string()
        } else {
            format!("{:+.2}%", metrics::improvement_pct(oga, run))
        };
        table.push(&[
            run.policy.clone(),
            format!("{:.2}", run.avg_reward()),
            format!("{:.1}", run.cumulative_reward),
            delta,
        ]);
    }
    println!("{}", table.render());
}
